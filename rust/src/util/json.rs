//! Minimal JSON: value type, recursive-descent parser, writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), sensor-event
//! payloads, metric exports, and workflow run records.  Deliberately small:
//! no serde (offline build), strings are owned, numbers are f64 with an i64
//! fast path preserved through [`Json::Int`].

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Integers are kept distinct from floats so event ids and
/// offsets round-trip exactly.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Path lookup: `j.path(&["a", "b"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(f) => write_f64(out, *f),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    x.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn write_f64(out: &mut String, f: f64) {
    if f.is_finite() {
        let _ = write!(out, "{f}");
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document (full input must be consumed).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode multi-byte UTF-8 from the original slice.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| self.err("bad number"))
        } else {
            text.parse::<i64>()
                .map(Json::Int)
                .or_else(|_| text.parse::<f64>().map(Json::Num))
                .map_err(|_| self.err("bad number"))
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        b if b >= 0xF0 => 4,
        b if b >= 0xE0 => 3,
        b if b >= 0xC0 => 2,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "42", "-7", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "src={src}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn int_roundtrips_exactly() {
        let v = parse("9007199254740993").unwrap(); // 2^53+1: f64 would lose it
        assert_eq!(v.as_i64(), Some(9007199254740993));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let mut o = Json::obj();
        o.set("k", Json::Str("a\"b\\c\nd\te\u{0007}".into()));
        let parsed = parse(&o.to_string()).unwrap();
        assert_eq!(parsed, o);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo ✓ é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓ é");
    }

    #[test]
    fn pretty_parses_back() {
        let mut o = Json::obj();
        o.set("arr", Json::Arr(vec![Json::Int(1), Json::Int(2)]));
        o.set("obj", {
            let mut inner = Json::obj();
            inner.set("x", Json::Num(1.5));
            inner
        });
        assert_eq!(parse(&o.to_pretty()).unwrap(), o);
    }

    #[test]
    fn sensor_event_shape() {
        // The exact shape wgen emits (Sec. 3.2: timestamp, sensor id, temp).
        let e = parse(r#"{"ts":1714329600000000,"id":17,"t":21.5}"#).unwrap();
        assert_eq!(e.get("id").unwrap().as_i64(), Some(17));
        assert!(e.get("t").unwrap().as_f64().unwrap() > 21.0);
    }
}
