//! Mini property-testing framework (proptest is not vendored offline).
//!
//! Deterministic: every case derives from a master seed, and a failing
//! case reports the seed + a bounded shrink of its inputs.  Used across
//! the suite for coordinator invariants (routing, batching, state),
//! broker log laws, and config round-trips.
//!
//! ```no_run
//! use sprobench::util::proptest::{Config, Gen, check};
//! check(Config::default().cases(64), "sorted idempotent", |g| {
//!     let mut v = g.vec_u64(0..100, 0, 32);
//!     v.sort();
//!     let w = {{ let mut w = v.clone(); w.sort(); w }};
//!     if v != w { return Err(format!("{v:?} != {w:?}")); }
//!     Ok(())
//! });
//! ```

use std::ops::Range;

use super::rng::Pcg32;

/// Property-run configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 100,
            // Honour SPROBENCH_PROPTEST_SEED for reproduction of failures.
            seed: std::env::var("SPROBENCH_PROPTEST_SEED")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0xC0FF_EE00),
        }
    }
}

impl Config {
    pub fn cases(mut self, n: u32) -> Self {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Input generator handed to each property case.
pub struct Gen {
    rng: Pcg32,
    /// Shrink pressure in [0,1]: later shrink attempts bias toward small inputs.
    shrink: f64,
}

impl Gen {
    fn new(seed: u64, case: u64, shrink: f64) -> Self {
        Self {
            rng: Pcg32::from_master(seed, case),
            shrink,
        }
    }

    pub fn u64(&mut self, range: Range<u64>) -> u64 {
        assert!(range.start < range.end);
        let span = range.end - range.start;
        let hi = if self.shrink > 0.0 {
            // Shrink by shrinking the effective span toward 1.
            let keep = ((1.0 - self.shrink) * span as f64).max(1.0) as u64;
            range.start + keep
        } else {
            range.end
        };
        self.rng.range_u64(range.start, hi - 1)
    }

    pub fn usize(&mut self, range: Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    pub fn i64(&mut self, range: Range<i64>) -> i64 {
        let span = (range.end - range.start) as u64;
        range.start + self.u64(0..span) as i64
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn vec_u64(&mut self, each: Range<u64>, min_len: usize, max_len: usize) -> Vec<u64> {
        let len = self.usize(min_len..max_len + 1);
        (0..len).map(|_| self.u64(each.clone())).collect()
    }

    pub fn vec_f32(&mut self, lo: f32, hi: f32, min_len: usize, max_len: usize) -> Vec<f32> {
        let len = self.usize(min_len..max_len + 1);
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }

    pub fn string(&mut self, max_len: usize) -> String {
        let len = self.usize(0..max_len + 1);
        (0..len)
            .map(|_| {
                let c = self.rng.below(95) as u8 + 32; // printable ASCII
                c as char
            })
            .collect()
    }

    /// Pick one of the provided values.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }
}

/// Run `property` for `config.cases` cases. On failure, retry the failing
/// case at increasing shrink pressure and report the smallest failure.
///
/// Panics (test failure) with seed + case + message on any failing case.
pub fn check<F>(config: Config, name: &str, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..config.cases {
        let mut g = Gen::new(config.seed, case as u64, 0.0);
        if let Err(msg) = property(&mut g) {
            // Shrink: same case seed, increasing pressure toward minimal inputs.
            let mut best = msg;
            let mut best_shrink = 0.0;
            for step in 1..=8 {
                let pressure = step as f64 / 8.0;
                let mut g = Gen::new(config.seed, case as u64, pressure);
                if let Err(m) = property(&mut g) {
                    best = m;
                    best_shrink = pressure;
                }
            }
            panic!(
                "property '{name}' failed (seed={:#x}, case={case}, shrink={best_shrink}): {best}\n\
                 reproduce with SPROBENCH_PROPTEST_SEED={}",
                config.seed, config.seed
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default().cases(50), "add-commutes", |g| {
            let a = g.u64(0..1000);
            let b = g.u64(0..1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check(Config::default().cases(5), "always-fails", |_g| {
            Err("nope".into())
        });
    }

    #[test]
    fn generators_respect_ranges() {
        check(Config::default().cases(200), "ranges", |g| {
            let v = g.u64(10..20);
            if !(10..20).contains(&v) {
                return Err(format!("u64 out of range: {v}"));
            }
            let f = g.f64(-1.0, 1.0);
            if !(-1.0..=1.0).contains(&f) {
                return Err(format!("f64 out of range: {f}"));
            }
            let s = g.string(16);
            if s.len() > 16 {
                return Err("string too long".into());
            }
            let xs = g.vec_u64(0..5, 2, 8);
            if xs.len() < 2 || xs.len() > 8 {
                return Err("vec len out of range".into());
            }
            Ok(())
        });
    }

    #[test]
    fn same_seed_same_cases() {
        let mut first = Vec::new();
        check(Config::default().cases(10).seed(99), "collect-a", |g| {
            first.push(g.u64(0..1_000_000));
            Ok(())
        });
        let mut second = Vec::new();
        check(Config::default().cases(10).seed(99), "collect-b", |g| {
            second.push(g.u64(0..1_000_000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
