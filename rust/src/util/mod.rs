//! Foundation utilities: everything the rest of the suite builds on.
//!
//! All of these exist because the build environment is offline (only the
//! `xla` crate closure is vendored); each is a small, tested substrate:
//!
//! * [`rng`] — PCG32/SplitMix64 PRNGs (deterministic, seedable).
//! * [`clock`] — wall + virtual clocks behind one trait (sim mode).
//! * [`histogram`] — HDR-style log-bucketed latency histogram.
//! * [`json`] — minimal JSON value/parser/writer (manifest, events, reports).
//! * [`chan`] — bounded MPMC channel with backpressure (broker substrate).
//! * [`pool`] — fixed worker thread pool.
//! * [`stats`] — mean/stddev/percentile/linear-regression helpers.
//! * [`units`] — "500K"/"8M"-style quantity parsing and formatting.
//! * [`proptest`] — mini property-testing framework (deterministic,
//!   bounded shrinking) used across coordinator invariants.
//! * [`logger`] — leveled stderr logger.

pub mod chan;
pub mod clock;
pub mod histogram;
pub mod json;
pub mod logger;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod units;
