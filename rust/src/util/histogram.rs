//! HDR-style log-bucketed histogram for latency recording.
//!
//! Criterion/hdrhistogram are unavailable offline, so this is the suite's
//! latency datatype: fixed memory, O(1) record, ~2.4% relative error per
//! bucket (64 sub-buckets per octave), mergeable across threads.

/// Log-bucketed histogram over `u64` values (microseconds by convention).
#[derive(Clone)]
pub struct Histogram {
    /// 64 sub-buckets per power of two, 40 octaves (values < 2^40 us ≈ 12.7d).
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUB_BITS: u32 = 6; // 64 sub-buckets per octave
const SUB: usize = 1 << SUB_BITS;
const OCTAVES: usize = 40;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; SUB * OCTAVES],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    fn index(value: u64) -> usize {
        if value < SUB as u64 {
            return value as usize;
        }
        let msb = 63 - value.leading_zeros();
        let octave = (msb - SUB_BITS + 1) as usize;
        let sub = (value >> (msb - SUB_BITS)) as usize & (SUB - 1);
        (octave * SUB + sub).min(SUB * OCTAVES - 1)
    }

    /// Representative (upper-bound) value for a bucket index.
    fn bucket_value(idx: usize) -> u64 {
        let octave = idx / SUB;
        let sub = (idx % SUB) as u64;
        if octave == 0 {
            return sub;
        }
        let base = 1u64 << (octave as u32 + SUB_BITS - 1);
        base + (sub + 1) * (base >> SUB_BITS) - 1
    }

    #[inline]
    pub fn record(&mut self, value: u64) {
        self.counts[Self::index(value)] += 1;
        self.total += 1;
        self.sum += value as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    #[inline]
    pub fn record_n(&mut self, value: u64, n: u64) {
        self.counts[Self::index(value)] += n;
        self.total += n;
        self.sum += value as u128 * n as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Value at quantile `q` in `[0,1]` (bucket upper bound; exact for
    /// values < 64, ≤2.4% relative error above).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                return Self::bucket_value(i).min(self.max).max(self.min);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one (cross-thread aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn reset(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.total = 0;
        self.sum = 0;
        self.min = u64::MAX;
        self.max = 0;
    }

    /// Compact summary for reports.
    pub fn summary(&self) -> HistogramSummary {
        HistogramSummary {
            count: self.count(),
            mean: self.mean(),
            min: self.min(),
            p50: self.p50(),
            p95: self.p95(),
            p99: self.p99(),
            max: self.max(),
        }
    }
}

/// Point-in-time summary of a [`Histogram`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistogramSummary {
    pub count: u64,
    pub mean: f64,
    pub min: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
    pub max: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0);
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = Histogram::new();
        for v in 0..64 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 63);
        // rank = ceil(0.5 * 64) = 32 → the 32nd smallest of {0..63} is 31.
        assert_eq!(h.quantile(0.5), 31);
    }

    #[test]
    fn quantile_error_is_bounded() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        for &(q, expect) in &[(0.5, 50_000.0), (0.95, 95_000.0), (0.99, 99_000.0)] {
            let got = h.quantile(q) as f64;
            let rel = (got - expect).abs() / expect;
            assert!(rel < 0.03, "q={q} got={got} expect={expect} rel={rel}");
        }
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        assert_eq!(h.mean(), 25.0);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for v in 0..1000u64 {
            if v % 2 == 0 {
                a.record(v * 3);
            } else {
                b.record(v * 3);
            }
            c.record(v * 3);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.mean(), c.mean());
        assert_eq!(a.quantile(0.9), c.quantile(0.9));
    }

    #[test]
    fn record_n_matches_loop() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_n(500, 10);
        for _ in 0..10 {
            b.record(500);
        }
        assert_eq!(a.summary(), b.summary());
    }

    #[test]
    fn huge_values_do_not_panic() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        assert_eq!(h.count(), 1);
        assert!(h.quantile(1.0) > 0);
    }

    #[test]
    fn reset_clears() {
        let mut h = Histogram::new();
        h.record(42);
        h.reset();
        assert!(h.is_empty());
        assert_eq!(h.max(), 0);
    }

    // --- property tests: the quantile laws the watermark-lag and
    // event-time latency metrics lean on ---------------------------------

    use crate::util::proptest::{check, Config as PtConfig};

    /// Random histogram over a wide dynamic range (mixes exact small
    /// values with bucketed large ones).
    fn arbitrary_histogram(g: &mut crate::util::proptest::Gen) -> Histogram {
        let mut h = Histogram::new();
        let n = g.usize(1..200);
        for _ in 0..n {
            // Spread across octaves: 2^0 .. 2^40.
            let shift = g.u64(0..40);
            h.record(g.u64(0..1_000) << shift);
        }
        h
    }

    #[test]
    fn prop_quantile_is_monotone_in_q() {
        check(PtConfig::default().cases(200), "quantile-monotone", |g| {
            let h = arbitrary_histogram(g);
            let q1 = g.f64(0.0, 1.0);
            let q2 = g.f64(0.0, 1.0);
            let (lo, hi) = if q1 <= q2 { (q1, q2) } else { (q2, q1) };
            let (vlo, vhi) = (h.quantile(lo), h.quantile(hi));
            if vlo > vhi {
                return Err(format!("q{lo:.3}={vlo} > q{hi:.3}={vhi}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quantiles_clamped_to_min_max() {
        check(PtConfig::default().cases(200), "quantile-clamped", |g| {
            let h = arbitrary_histogram(g);
            for q in [0.0, 0.001, 0.25, 0.5, 0.9, 0.999, 1.0] {
                let v = h.quantile(q);
                if v < h.min() || v > h.max() {
                    return Err(format!(
                        "q{q}={v} outside [{}, {}]",
                        h.min(),
                        h.max()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_extreme_quantiles_hit_the_bounds() {
        check(PtConfig::default().cases(200), "quantile-extremes", |g| {
            let h = arbitrary_histogram(g);
            // q=1 is exactly the maximum (bucket upper bound clamps down).
            if h.quantile(1.0) != h.max() {
                return Err(format!("q1={} != max={}", h.quantile(1.0), h.max()));
            }
            // q=0 lands in the minimum's bucket: never below the min,
            // never past its bucket's representative error bound.
            let q0 = h.quantile(0.0);
            if q0 < h.min() {
                return Err(format!("q0={q0} < min={}", h.min()));
            }
            let bound = h.min() + (h.min() >> 5) + 1; // ≤ one sub-bucket up
            if q0 > bound.min(h.max()) {
                return Err(format!("q0={q0} beyond min's bucket ({bound})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_single_sample_is_every_quantile() {
        check(PtConfig::default().cases(200), "single-sample", |g| {
            let v = g.u64(0..u64::MAX >> 1);
            let mut h = Histogram::new();
            h.record(v);
            for q in [0.0, 0.5, 1.0] {
                // min == max == v, so clamping pins every quantile to v.
                if h.quantile(q) != v {
                    return Err(format!("q{q}={} != {v}", h.quantile(q)));
                }
            }
            if h.min() != v || h.max() != v {
                return Err("min/max of a single sample must be the sample".into());
            }
            Ok(())
        });
    }
}
