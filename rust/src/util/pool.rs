//! Fixed-size worker thread pool.
//!
//! Used by the broker for its "network" and "I/O" thread pools (the paper's
//! Kafka configuration exposes exactly those two knobs — Sec. 4: "20 threads
//! for I/O and 10 threads for network operations") and by the workflow
//! runner for concurrent experiments.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::chan::{bounded, RecvTimeout, Sender};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of named worker threads consuming a bounded job queue.
pub struct ThreadPool {
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// `queue_depth` bounds pending jobs — submitting beyond it blocks,
    /// propagating backpressure to the caller.
    pub fn new(name: &str, threads: usize, queue_depth: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = bounded::<Job>(queue_depth.max(1));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = rx.clone();
                let in_flight = in_flight.clone();
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        match rx.recv_timeout(std::time::Duration::from_millis(100)) {
                            RecvTimeout::Item(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            RecvTimeout::TimedOut => continue,
                            RecvTimeout::Closed => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx,
            workers,
            in_flight,
        }
    }

    /// Submit a job; blocks when the queue is full (backpressure).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        if self.tx.send(Box::new(job)).is_err() {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            panic!("submit on shut-down pool");
        }
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            std::thread::yield_now();
        }
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.tx.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new("t", 4, 64);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        pool.shutdown();
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new("p", 4, 16);
        let (tx, rx) = bounded::<()>(4);
        // 4 jobs that each wait for all 4 to be running: only possible if
        // the pool really runs them concurrently.
        let barrier = Arc::new(std::sync::Barrier::new(4));
        for _ in 0..4 {
            let b = barrier.clone();
            let tx = tx.clone();
            pool.submit(move || {
                b.wait();
                tx.send(()).unwrap();
            });
        }
        for _ in 0..4 {
            assert!(matches!(
                rx.recv_timeout(std::time::Duration::from_secs(5)),
                RecvTimeout::Item(())
            ));
        }
        pool.shutdown();
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new("d", 2, 8);
        pool.submit(|| {});
        drop(pool); // must not hang or panic
    }
}
