//! Bounded MPMC channel with blocking backpressure.
//!
//! The broker's partitions and the engine's task queues need a bounded
//! queue whose `send` blocks when full (that *is* the backpressure signal
//! the paper's pipelines exhibit).  std::sync::mpsc is MPSC and unbounded
//! or rendezvous-ish; crossbeam-channel is not vendored — so: a Mutex +
//! two Condvars around a VecDeque.  Simple, correct, and fast enough that
//! the hot path (which batches) is never channel-limited; verified by
//! `benches/hotpath_micro.rs`.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Sending half; clonable (MPMC).
pub struct Sender<T> {
    inner: Arc<Inner<T>>,
}

/// Receiving half; clonable (MPMC).
pub struct Receiver<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Self {
            inner: self.inner.clone(),
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Self {
            inner: self.inner.clone(),
        }
    }
}

/// Error returned when the channel is closed.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

/// Result of a timed receive.
#[derive(Debug, PartialEq, Eq)]
pub enum RecvTimeout<T> {
    Item(T),
    TimedOut,
    Closed,
}

pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0);
    let inner = Arc::new(Inner {
        queue: Mutex::new(State {
            items: VecDeque::with_capacity(capacity),
            closed: false,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        capacity,
    });
    (
        Sender {
            inner: inner.clone(),
        },
        Receiver { inner },
    )
}

impl<T> Sender<T> {
    /// Blocking send; applies backpressure when the queue is full.
    pub fn send(&self, item: T) -> Result<(), Closed> {
        let mut st = self.inner.queue.lock().expect("chan poisoned");
        while st.items.len() >= self.inner.capacity {
            if st.closed {
                return Err(Closed);
            }
            st = self.inner.not_full.wait(st).expect("chan poisoned");
        }
        if st.closed {
            return Err(Closed);
        }
        st.items.push_back(item);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking send: returns the item back if the queue is full.
    pub fn try_send(&self, item: T) -> Result<(), TrySendError<T>> {
        let mut st = self.inner.queue.lock().expect("chan poisoned");
        if st.closed {
            return Err(TrySendError::Closed(item));
        }
        if st.items.len() >= self.inner.capacity {
            return Err(TrySendError::Full(item));
        }
        st.items.push_back(item);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Close the channel; receivers drain remaining items then see `Closed`.
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().expect("chan poisoned");
        st.closed = true;
        drop(st);
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().expect("chan poisoned").items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.capacity
    }
}

#[derive(Debug)]
pub enum TrySendError<T> {
    Full(T),
    Closed(T),
}

impl<T> Receiver<T> {
    /// Blocking receive; returns `Err(Closed)` once closed *and* drained.
    pub fn recv(&self) -> Result<T, Closed> {
        let mut st = self.inner.queue.lock().expect("chan poisoned");
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Ok(item);
            }
            if st.closed {
                return Err(Closed);
            }
            st = self.inner.not_empty.wait(st).expect("chan poisoned");
        }
    }

    /// Receive with timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> RecvTimeout<T> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.inner.queue.lock().expect("chan poisoned");
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return RecvTimeout::Item(item);
            }
            if st.closed {
                return RecvTimeout::Closed;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return RecvTimeout::TimedOut;
            }
            let (next, timed_out) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .expect("chan poisoned");
            st = next;
            if timed_out.timed_out() && st.items.is_empty() {
                if st.closed {
                    return RecvTimeout::Closed;
                }
                return RecvTimeout::TimedOut;
            }
        }
    }

    /// Drain up to `max` items without blocking (batch consumption).
    pub fn drain_into(&self, buf: &mut Vec<T>, max: usize) -> usize {
        let mut st = self.inner.queue.lock().expect("chan poisoned");
        let n = max.min(st.items.len());
        for _ in 0..n {
            buf.push(st.items.pop_front().expect("len checked"));
        }
        drop(st);
        // Wake exactly as many blocked senders as slots freed: notify_all
        // here was a thundering herd — every blocked sender woke, one won
        // the slot, and the rest re-queued on the condvar having paid a
        // wakeup + mutex round-trip for nothing.
        for _ in 0..n {
            self.inner.not_full.notify_one();
        }
        n
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().expect("chan poisoned").items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn try_send_full() {
        let (tx, _rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        match tx.try_send(3) {
            Err(TrySendError::Full(3)) => {}
            other => panic!("expected Full, got {other:?}"),
        }
    }

    #[test]
    fn backpressure_blocks_until_consumed() {
        let (tx, rx) = bounded(1);
        tx.send(0u32).unwrap();
        let t = thread::spawn(move || {
            tx.send(1).unwrap(); // blocks until the consumer pops
            tx.send(2).unwrap();
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 0);
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        t.join().unwrap();
    }

    #[test]
    fn close_drains_then_errors() {
        let (tx, rx) = bounded(4);
        tx.send("a").unwrap();
        tx.send("b").unwrap();
        tx.close();
        assert_eq!(rx.recv().unwrap(), "a");
        assert_eq!(rx.recv().unwrap(), "b");
        assert_eq!(rx.recv(), Err(Closed));
        assert_eq!(tx.send("c"), Err(Closed));
    }

    #[test]
    fn recv_timeout_times_out() {
        let (_tx, rx) = bounded::<u32>(1);
        match rx.recv_timeout(Duration::from_millis(10)) {
            RecvTimeout::TimedOut => {}
            other => panic!("expected timeout, got {other:?}"),
        }
    }

    #[test]
    fn drain_into_batches() {
        let (tx, rx) = bounded(64);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let mut buf = Vec::new();
        assert_eq!(rx.drain_into(&mut buf, 4), 4);
        assert_eq!(buf, vec![0, 1, 2, 3]);
        assert_eq!(rx.drain_into(&mut buf, 100), 6);
        assert_eq!(buf.len(), 10);
    }

    #[test]
    fn drain_wakes_exactly_the_freed_slots() {
        let (tx, rx) = bounded(2);
        tx.send(0).unwrap();
        tx.send(1).unwrap();
        // Three senders block on the full queue.
        let senders: Vec<_> = (2..5)
            .map(|i| {
                let tx = tx.clone();
                thread::spawn(move || tx.send(i).unwrap())
            })
            .collect();
        thread::sleep(Duration::from_millis(30));
        let mut buf = Vec::new();
        // Freeing 2 slots wakes 2 senders; the third stays parked.
        assert_eq!(rx.drain_into(&mut buf, 2), 2);
        thread::sleep(Duration::from_millis(50));
        assert_eq!(rx.len(), 2, "woken senders should refill freed slots");
        // Free the last slot; everything drains and nothing is lost.
        assert_eq!(rx.drain_into(&mut buf, 2), 2);
        for s in senders {
            s.join().unwrap();
        }
        rx.drain_into(&mut buf, 10);
        buf.sort_unstable();
        assert_eq!(buf, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(16);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..250 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        tx.close();
        let mut all: Vec<i32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all.len(), 1000);
        all.dedup();
        assert_eq!(all.len(), 1000, "duplicates delivered");
    }
}
