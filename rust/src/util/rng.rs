//! Deterministic PRNGs: SplitMix64 (seeding) and PCG32 (streams).
//!
//! Every stochastic component in the suite (workload patterns, broker
//! jitter, schedulers, property tests) draws from these so that a run is
//! fully reproducible from the seed recorded in its run directory.

/// SplitMix64 — used to expand one user seed into independent stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): small, fast, statistically solid for benchmarks.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a PCG stream from a master seed and a stream id.
    pub fn from_master(master: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(master ^ stream.wrapping_mul(0xA24B_AED4_963E_E407));
        Self::new(sm.next_u64(), sm.next_u64())
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut m = (self.next_u32() as u64).wrapping_mul(bound as u64);
        let mut lo = m as u32;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                m = (self.next_u32() as u64).wrapping_mul(bound as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        // Use 32-bit path where possible for speed.
        if span < u32::MAX as u64 {
            lo + self.below(span as u32 + 1) as u64
        } else {
            lo + self.next_u64() % (span + 1) // rare path; bias negligible
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Exponential with rate `lambda` (inter-arrival sampling).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        loop {
            let u = self.f64();
            if u > 1e-12 {
                return -u.ln() / lambda;
            }
        }
    }

    /// Pick one element of a slice uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

/// Zipf-distributed key sampler (hot-key skew for keyed workloads).
///
/// Rejection-inversion (Hörmann/Derflinger) is overkill here; the benchmark
/// uses modest `n`, so we precompute the CDF once and binary-search.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, exponent: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(exponent);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    /// Sample a key in `[0, n)`; key 0 is hottest.
    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).expect("cdf is finite"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::from_master(1, 0);
        let mut b = Pcg32::from_master(1, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "independent streams should rarely collide");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::new(7, 3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::new(9, 1);
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut rng = Pcg32::new(11, 2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg32::new(13, 5);
        let n = 50_000;
        let lambda = 4.0;
        let mean = (0..n).map(|_| rng.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Pcg32::new(17, 8);
        let z = Zipf::new(100, 1.0);
        let mut counts = [0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
    }

    #[test]
    fn range_u64_inclusive_bounds() {
        let mut rng = Pcg32::new(23, 1);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..20_000 {
            let v = rng.range_u64(5, 8);
            assert!((5..=8).contains(&v));
            hit_lo |= v == 5;
            hit_hi |= v == 8;
        }
        assert!(hit_lo && hit_hi);
    }
}
