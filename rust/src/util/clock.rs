//! Wall and virtual clocks behind a single trait.
//!
//! The suite runs in two execution modes (DESIGN.md §1): `wall` drives real
//! threads with real time; `sim` advances a shared virtual clock so the
//! SLURM scheduler and cluster-scale extrapolations run instantly and
//! deterministically.  All components take a [`ClockRef`] so either mode
//! plugs in.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

/// Microsecond-resolution clock abstraction.
pub trait Clock: Send + Sync {
    /// Current time in microseconds (epoch origin for wall, 0-origin for sim).
    fn now_micros(&self) -> u64;
    /// Sleep (wall) or advance the virtual clock (sim).
    fn sleep_micros(&self, micros: u64);
    /// True when this is a virtual clock.
    fn is_virtual(&self) -> bool {
        false
    }
}

pub type ClockRef = Arc<dyn Clock>;

/// Real time, backed by `std::time`.
#[derive(Default)]
pub struct WallClock;

impl Clock for WallClock {
    fn now_micros(&self) -> u64 {
        SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .expect("clock before epoch")
            .as_micros() as u64
    }

    fn sleep_micros(&self, micros: u64) {
        if micros > 0 {
            std::thread::sleep(std::time::Duration::from_micros(micros));
        }
    }
}

/// Shared virtual clock: `sleep` advances time atomically, `now` reads it.
///
/// Components in sim mode run sequentially (the discrete-event loop in
/// [`crate::slurm::scheduler`] and [`crate::coordinator::simrun`] owns
/// ordering), so a single atomic counter is sufficient.
#[derive(Default)]
pub struct SimClock {
    micros: AtomicU64,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn starting_at(micros: u64) -> Self {
        Self {
            micros: AtomicU64::new(micros),
        }
    }

    /// Jump the clock to `t` (used by event-loop dispatch). Never rewinds.
    pub fn advance_to(&self, t: u64) {
        self.micros.fetch_max(t, Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now_micros(&self) -> u64 {
        self.micros.load(Ordering::SeqCst)
    }

    fn sleep_micros(&self, micros: u64) {
        self.micros.fetch_add(micros, Ordering::SeqCst);
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

/// Convenience constructors.
pub fn wall() -> ClockRef {
    Arc::new(WallClock)
}

pub fn sim() -> ClockRef {
    Arc::new(SimClock::new())
}

/// Monotonic stopwatch over any clock.
pub struct Stopwatch {
    clock: ClockRef,
    start: u64,
}

impl Stopwatch {
    pub fn start(clock: ClockRef) -> Self {
        let start = clock.now_micros();
        Self { clock, start }
    }

    pub fn elapsed_micros(&self) -> u64 {
        self.clock.now_micros().saturating_sub(self.start)
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed_micros() as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotonic_enough() {
        let c = wall();
        let a = c.now_micros();
        c.sleep_micros(2_000);
        let b = c.now_micros();
        assert!(b >= a + 1_000, "slept 2ms but advanced {}us", b - a);
    }

    #[test]
    fn sim_clock_advances_on_sleep() {
        let c = sim();
        assert_eq!(c.now_micros(), 0);
        c.sleep_micros(1_000_000);
        assert_eq!(c.now_micros(), 1_000_000);
        assert!(c.is_virtual());
    }

    #[test]
    fn sim_clock_advance_to_never_rewinds() {
        let c = SimClock::new();
        c.advance_to(500);
        c.advance_to(200);
        assert_eq!(c.now_micros(), 500);
    }

    #[test]
    fn stopwatch_over_sim_clock() {
        let c: ClockRef = Arc::new(SimClock::new());
        let sw = Stopwatch::start(c.clone());
        c.sleep_micros(2_500_000);
        assert_eq!(sw.elapsed_micros(), 2_500_000);
        assert!((sw.elapsed_secs() - 2.5).abs() < 1e-9);
    }
}
