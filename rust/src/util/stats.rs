//! Scalar statistics helpers: summary stats, percentiles on raw samples,
//! and ordinary-least-squares linear regression.
//!
//! The linear fit is how `EXPERIMENTS.md` quantifies the paper's Fig. 6
//! claim ("consistent 1:1 relationship", "linear scaling"): we regress
//! broker-out throughput against generator-offered load and report
//! slope + R².

/// Running mean/variance (Welford) + min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Percentile over a raw sample set (exact; sorts a copy).
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("no NaN in samples"));
    let rank = (q.clamp(0.0, 1.0) * (v.len() - 1) as f64).round() as usize;
    v[rank]
}

/// Result of an OLS fit `y = slope * x + intercept`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    pub r2: f64,
}

/// Ordinary least squares over paired samples. Panics if lengths differ.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> LinearFit {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return LinearFit {
            slope: 0.0,
            intercept: 0.0,
            r2: 0.0,
        };
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 {
        return LinearFit {
            slope: 0.0,
            intercept: my,
            r2: 0.0,
        };
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    LinearFit {
        slope,
        intercept,
        r2,
    }
}

/// Geometric mean (speedup aggregation across workloads).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.stddev() - 2.138_089_935).abs() < 1e-6);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentile_exact() {
        let v: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.5), 50.0);
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
    }

    #[test]
    fn perfect_linear_fit() {
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        let f = linear_fit(&xs, &ys);
        assert!((f.slope - 2.0).abs() < 1e-12);
        assert!((f.intercept - 1.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_fit_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| x + if i % 2 == 0 { 3.0 } else { -3.0 })
            .collect();
        let f = linear_fit(&xs, &ys);
        assert!(f.r2 < 1.0 && f.r2 > 0.9);
        assert!((f.slope - 1.0).abs() < 0.05);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn constant_x_degenerate_fit() {
        let f = linear_fit(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]);
        assert_eq!(f.slope, 0.0);
        assert_eq!(f.intercept, 2.0);
    }
}
