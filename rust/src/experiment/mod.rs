//! Automated max-capacity experiments (paper Sec. 3: "built-in automated
//! experiment management tools" that push a framework to its scalability
//! limit).
//!
//! A single spot run answers "what happened at rate R"; this module
//! answers "what is the highest R the system sustains".  It implements
//! the stepped-load methodology of Karimov et al. and ShuffleBench:
//!
//! * [`sustain`] — the sustainability predicate over a finished
//!   [`crate::coordinator::RunSummary`] and its metric timeline.
//! * [`driver`] — [`MaxCapacityDriver`]: geometric load escalation, then
//!   binary-search refinement of the knee, around any spot-run entry
//!   point (wall or sim).
//! * [`report`] — [`ExperimentReport`]: machine-readable JSON plus a
//!   Markdown summary of every probe and the final maximum sustainable
//!   throughput (MST).
//!
//! Reached from the CLI as `sprobench max-capacity --config <yaml>`; the
//! sweep's knobs live in the config's `experiment:` section
//! ([`crate::config::schema::ExperimentSection`]).

pub mod driver;
pub mod report;
pub mod sustain;

pub use driver::MaxCapacityDriver;
pub use report::{config_fingerprint, ExperimentReport, IterationRecord, Phase};
pub use sustain::{SustainPolicy, Verdict};
