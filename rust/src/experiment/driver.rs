//! The max-capacity escalation driver.
//!
//! [`MaxCapacityDriver`] wraps any spot-run entry point — normally
//! [`crate::coordinator::run_wall`] or [`crate::coordinator::simrun::run_sim`]
//! — in a stepped-load loop: probe at the starting rate, multiply the
//! target by `experiment.step_factor` while the sustainability predicate
//! ([`super::SustainPolicy`]) holds, then binary-search the bracket
//! between the last sustained and the first failing rate.  The result is
//! the benchmark's headline number: the **maximum sustainable
//! throughput** (MST), plus a full [`ExperimentReport`] of every probe.
//!
//! The runner is injected as a closure so the escalation logic itself is
//! deterministic and unit-testable against synthetic capacity models.

use std::sync::Arc;

use crate::config::BenchConfig;
use crate::coordinator::RunSummary;
use crate::metrics::{MeasurementPoint, MetricStore};

use super::report::{config_fingerprint, ExperimentReport, IterationRecord, Phase};
use super::sustain::SustainPolicy;

/// Upper clamp on probe rates; keeps `rate * step_factor` well inside
/// both u64 and the f64 integer range however long the sweep runs.
const MAX_PROBE_RATE: u64 = 1_000_000_000_000;

/// Drives one escalation sweep over a base configuration.
pub struct MaxCapacityDriver<R> {
    base: BenchConfig,
    runner: R,
}

impl<R> MaxCapacityDriver<R>
where
    R: FnMut(&BenchConfig) -> Result<(RunSummary, Arc<MetricStore>), String>,
{
    /// `base` supplies everything but the per-probe rate; its
    /// `experiment:` section controls the sweep.  `runner` executes one
    /// spot run and returns its summary + timeline.
    pub fn new(base: BenchConfig, runner: R) -> Self {
        Self { base, runner }
    }

    /// Run the full sweep: escalation, then binary-search refinement.
    pub fn run(&mut self) -> Result<ExperimentReport, String> {
        let policy = SustainPolicy::from_config(&self.base);
        let exp = self.base.experiment.clone();
        let step = exp.step_factor;

        let mut iterations: Vec<IterationRecord> = Vec::new();
        let mut best_ok: Option<(u64, f64)> = None; // (target, processed rate)
        let mut first_fail: Option<u64> = None;

        // Phase 1: geometric escalation until the predicate fails.
        let start = if exp.start_rate > 0 {
            exp.start_rate
        } else {
            self.base.workload.rate
        };
        let mut rate = start.clamp(1, MAX_PROBE_RATE);
        for _ in 0..exp.max_iterations {
            let rec = self.probe(rate, Phase::Escalate, iterations.len() as u32, &policy)?;
            let ok = rec.sustainable;
            let processed = rec.processed_rate;
            iterations.push(rec);
            if ok {
                best_ok = Some((rate, processed));
                let next = ((rate as f64) * step).ceil() as u64;
                rate = next.max(rate.saturating_add(1)).min(MAX_PROBE_RATE);
            } else {
                first_fail = Some(rate);
                break;
            }
        }

        // Phase 2: binary-search the knee inside the bracket.  When the
        // very first probe failed there is no sustained lower bound; the
        // search then descends from the failing rate toward zero.
        if let Some(fail) = first_fail {
            let mut lo = best_ok.map(|(t, _)| t).unwrap_or(0);
            let mut hi = fail;
            for _ in 0..exp.refine_steps {
                let mid = lo + (hi - lo) / 2;
                if mid == lo || mid == hi {
                    break;
                }
                let rec = self.probe(mid, Phase::Refine, iterations.len() as u32, &policy)?;
                let ok = rec.sustainable;
                let processed = rec.processed_rate;
                iterations.push(rec);
                if ok {
                    best_ok = Some((mid, processed));
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            first_fail = Some(hi);
        }

        // A knee needs both sides of the bracket: a sustained rate below
        // and a failing rate above.  All-probes-failed sweeps have no
        // sustained side, so they report no knee (and MST 0).
        let knee = match (best_ok, first_fail) {
            (Some((ok, _)), Some(fail)) => Some((ok, fail)),
            _ => None,
        };
        let (mst_target_rate, mst_processed_rate) = best_ok.unwrap_or((0, 0.0));
        Ok(ExperimentReport {
            name: self.base.bench.name.clone(),
            pipeline: self.base.engine.pipeline_label(),
            framework: self.base.engine.framework.name().to_string(),
            parallelism: self.base.engine.parallelism,
            config_fingerprint: config_fingerprint(&self.base),
            iterations,
            mst_target_rate,
            mst_processed_rate,
            knee,
        })
    }

    /// Execute one probe run at `target_rate` and fold the outcome into
    /// an [`IterationRecord`].
    fn probe(
        &mut self,
        target_rate: u64,
        phase: Phase,
        index: u32,
        policy: &SustainPolicy,
    ) -> Result<IterationRecord, String> {
        let mut cfg = self.base.clone();
        cfg.bench.name = format!("{}-{}{}", self.base.bench.name, phase.name(), index);
        cfg.workload.rate = target_rate;
        if cfg.experiment.iteration_duration_micros > 0 {
            cfg.bench.duration_micros = cfg.experiment.iteration_duration_micros;
        }
        // Auto-scale the fleet so the raised rate never trips config
        // validation; the paper's generator layer does the same.
        let cap = cfg.generators.instance_capacity.max(1);
        let needed = (target_rate + cap - 1) / cap;
        if needed > cfg.generators.max_instances as u64 {
            cfg.generators.max_instances = needed.min(u32::MAX as u64) as u32;
        }

        let (summary, store) = (self.runner)(&cfg)?;
        let verdict = policy.evaluate(target_rate, &summary, Some(&store));
        let e2e = summary.latency_at(MeasurementPoint::EndToEnd);
        Ok(IterationRecord {
            index,
            phase,
            target_rate,
            offered_rate: summary.offered_rate,
            processed_rate: summary.processed_rate,
            p50_us: e2e.map(|h| h.p50).unwrap_or(0),
            p95_us: e2e.map(|h| h.p95).unwrap_or(0),
            p99_us: e2e.map(|h| h.p99).unwrap_or(0),
            mean_us: e2e.map(|h| h.mean).unwrap_or(0.0),
            backlog: summary.generated.saturating_sub(summary.processed),
            elapsed_micros: summary.elapsed_micros,
            sustainable: verdict.sustainable,
            reasons: verdict.reasons,
            operators: summary.operators.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::histogram::HistogramSummary;
    use crate::util::rng::Pcg32;

    /// A synthetic system with a hard capacity: offers exactly the target,
    /// processes `min(target, capacity * (1 ± jitter))`, and shows
    /// saturating latency near the knee.  Seeded, hence deterministic.
    fn capacity_runner(
        capacity: f64,
        seed: u64,
    ) -> impl FnMut(&BenchConfig) -> Result<(RunSummary, Arc<MetricStore>), String> {
        let mut rng = Pcg32::from_master(seed, 0xCAFE);
        move |cfg: &BenchConfig| {
            let target = cfg.workload.rate as f64;
            let jitter = 1.0 + (rng.f64() - 0.5) * 0.01;
            let processed_rate = target.min(capacity * jitter);
            let duration_s = cfg.bench.duration_micros as f64 / 1e6;
            let generated = (target * duration_s) as u64;
            let processed = (processed_rate * duration_s) as u64;
            let rho = (processed_rate / capacity).min(0.999);
            let p50 = (500.0 / (1.0 - rho)) as u64;
            let summary = RunSummary {
                name: cfg.bench.name.clone(),
                pipeline: cfg.engine.pipeline_label(),
                framework: "flink",
                parallelism: cfg.engine.parallelism,
                generated,
                processed,
                emitted: processed,
                elapsed_micros: cfg.bench.duration_micros,
                offered_rate: target,
                processed_rate,
                offered_bytes_rate: target * 27.0,
                latency: vec![(
                    MeasurementPoint::EndToEnd,
                    HistogramSummary {
                        count: processed.max(1),
                        mean: p50 as f64 * 1.2,
                        min: 100,
                        p50,
                        p95: p50 * 2,
                        p99: p50 * 3,
                        max: p50 * 5,
                    },
                )],
                gc_young_count: 0,
                gc_young_time_micros: 0,
                energy_joules: 0.0,
                parse_failures: 0,
                batches: 1,
                operators: Vec::new(),
                recovery: None,
                quarantined: 0,
                faults: Vec::new(),
                resilience: None,
                transport: None,
            };
            Ok((summary, Arc::new(MetricStore::new())))
        }
    }

    fn sweep_cfg(start_rate: u64) -> BenchConfig {
        let mut cfg = BenchConfig::default();
        cfg.bench.name = "maxcap-test".into();
        cfg.bench.duration_micros = 2_000_000;
        cfg.experiment.start_rate = start_rate;
        cfg.experiment.step_factor = 2.0;
        cfg.experiment.max_iterations = 10;
        cfg.experiment.refine_steps = 6;
        cfg.experiment.sustain_ratio = 0.95;
        cfg
    }

    #[test]
    fn converges_to_the_synthetic_capacity() {
        let capacity = 1_000_000.0;
        let mut driver = MaxCapacityDriver::new(sweep_cfg(100_000), capacity_runner(capacity, 42));
        let report = driver.run().unwrap();
        let mst = report.mst_target_rate as f64;
        assert!(
            (0.85 * capacity..=1.1 * capacity).contains(&mst),
            "MST {mst} not near capacity {capacity}"
        );
        let knee = report.knee.expect("knee bracketed");
        assert!(knee.0 <= knee.1);
        assert_eq!(knee.0, report.mst_target_rate);
        // Escalation phase is geometric until the first failure.
        let escalate: Vec<&IterationRecord> = report
            .iterations
            .iter()
            .filter(|i| i.phase == Phase::Escalate)
            .collect();
        assert!(escalate.len() >= 4, "expected several doublings");
        for w in escalate.windows(2) {
            assert_eq!(w[1].target_rate, w[0].target_rate * 2);
        }
        assert!(escalate.last().unwrap().reasons.iter().any(|r| r.contains("fell behind")));
    }

    #[test]
    fn deterministic_under_a_fixed_seed() {
        let run = |seed| {
            MaxCapacityDriver::new(sweep_cfg(100_000), capacity_runner(1_000_000.0, seed))
                .run()
                .unwrap()
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b, "same seed must reproduce the sweep exactly");
        assert_eq!(a.config_fingerprint, b.config_fingerprint);
    }

    #[test]
    fn unbounded_capacity_never_finds_a_knee() {
        let mut driver =
            MaxCapacityDriver::new(sweep_cfg(100_000), capacity_runner(f64::INFINITY, 1));
        let report = driver.run().unwrap();
        assert!(report.knee.is_none());
        assert_eq!(report.iterations.len(), 10, "all escalation iterations used");
        assert!(report.iterations.iter().all(|i| i.sustainable));
        // MST is the last (highest) sustained target: start * 2^9.
        assert_eq!(report.mst_target_rate, 100_000 << 9);
    }

    #[test]
    fn first_probe_failure_searches_downward() {
        // Capacity far below the starting rate: the driver must refine
        // down from the failing start, not give up.
        let capacity = 200_000.0;
        let mut driver = MaxCapacityDriver::new(sweep_cfg(1_600_000), capacity_runner(capacity, 3));
        let report = driver.run().unwrap();
        assert!(!report.iterations[0].sustainable);
        assert!(report.mst_target_rate > 0, "refinement found a sustainable rate");
        let mst = report.mst_target_rate as f64;
        assert!(mst <= 1.1 * capacity, "MST {mst} above capacity {capacity}");
        assert!(report.iterations.iter().skip(1).all(|i| i.phase == Phase::Refine));
    }

    #[test]
    fn probe_runs_inherit_iteration_duration_and_autoscale() {
        let mut cfg = sweep_cfg(10_000_000);
        cfg.experiment.max_iterations = 1;
        cfg.experiment.iteration_duration_micros = 750_000;
        cfg.generators.max_instances = 4; // far too few for 10M ev/s
        let mut seen: Vec<(u64, u32, u64)> = Vec::new();
        let mut base = capacity_runner(f64::INFINITY, 9);
        let mut driver = MaxCapacityDriver::new(cfg, |c: &BenchConfig| {
            seen.push((
                c.bench.duration_micros,
                c.generators.max_instances,
                c.workload.rate,
            ));
            c.validate().map_err(|e| e.to_string())?;
            base(c)
        });
        driver.run().unwrap();
        drop(driver);
        assert_eq!(seen.len(), 1);
        let (duration, instances, rate) = seen[0];
        assert_eq!(duration, 750_000);
        assert_eq!(rate, 10_000_000);
        assert!(instances >= 20, "fleet must autoscale, got {instances}");
    }

    #[test]
    fn all_probes_failing_reports_no_knee_and_zero_mst() {
        // Capacity so low even the refinement floor fails: no sustained
        // rate exists, so there is nothing to bracket.
        let mut cfg = sweep_cfg(1_600_000);
        cfg.experiment.refine_steps = 3;
        let mut driver = MaxCapacityDriver::new(cfg, capacity_runner(10.0, 5));
        let report = driver.run().unwrap();
        assert!(report.iterations.iter().all(|i| !i.sustainable));
        assert_eq!(report.mst_target_rate, 0);
        assert!(report.knee.is_none(), "no sustained side → no knee");
        let md = report.to_markdown();
        assert!(md.contains("No sustainable rate found"));
        assert!(!md.contains("Knee bracket"));
    }

    #[test]
    fn runner_errors_propagate() {
        let mut driver = MaxCapacityDriver::new(sweep_cfg(100_000), |_: &BenchConfig| {
            Err("broker exploded".to_string())
        });
        assert!(driver.run().unwrap_err().contains("broker exploded"));
    }
}
