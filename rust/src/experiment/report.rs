//! Structured max-capacity experiment reports.
//!
//! An [`ExperimentReport`] captures every iteration of one escalation
//! sweep — target rate, measured rates, latency percentiles, and the
//! sustainability verdict — plus the detected knee point and the final
//! maximum sustainable throughput (MST).  It serializes to JSON
//! (`report.json`, round-trippable through [`ExperimentReport::from_json`])
//! and renders to a human-friendly Markdown summary (`report.md`) via
//! [`crate::postprocess::markdown_table`].

use crate::config::BenchConfig;
use crate::pipelines::StepStats;
use crate::postprocess::markdown_table;
use crate::util::json::Json;
use crate::util::units::{fmt_count, fmt_micros};

/// Which loop of the driver produced an iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Geometric load escalation (rate × step_factor each round).
    Escalate,
    /// Binary-search refinement between the bracketing rates.
    Refine,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Escalate => "escalate",
            Phase::Refine => "refine",
        }
    }

    pub fn from_name(s: &str) -> Option<Phase> {
        match s {
            "escalate" => Some(Phase::Escalate),
            "refine" => Some(Phase::Refine),
            _ => None,
        }
    }
}

/// One probe run inside the sweep.
#[derive(Clone, Debug, PartialEq)]
pub struct IterationRecord {
    pub index: u32,
    pub phase: Phase,
    /// Rate the driver asked the fleet for, events/s.
    pub target_rate: u64,
    /// Rate the fleet actually offered, events/s.
    pub offered_rate: f64,
    /// Rate the engine processed, events/s.
    pub processed_rate: f64,
    /// End-to-end latency percentiles, µs (0 when not recorded).
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    /// Events generated but unprocessed at run end.
    pub backlog: u64,
    pub elapsed_micros: u64,
    pub sustainable: bool,
    /// One entry per failed sustainability check; empty when sustainable.
    pub reasons: Vec<String>,
    /// Per-operator stats merged across engine tasks for this probe, in
    /// chain order (empty for sim probes and pre-chain reports).
    pub operators: Vec<(String, StepStats)>,
}

/// The complete sweep result.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentReport {
    pub name: String,
    pub pipeline: String,
    pub framework: String,
    pub parallelism: u32,
    /// FNV-1a fingerprint of the resolved base config, so reports from
    /// different configurations are never compared by accident.
    pub config_fingerprint: String,
    pub iterations: Vec<IterationRecord>,
    /// Highest target rate judged sustainable (events/s); 0 when none was.
    pub mst_target_rate: u64,
    /// Engine-processed rate measured at that target.
    pub mst_processed_rate: f64,
    /// The bracket around the knee: (highest sustained, lowest failing)
    /// target rates.  `None` when the sweep never saw a failure, or when
    /// no probe was sustainable (nothing to bracket from below).
    pub knee: Option<(u64, u64)>,
}

/// FNV-1a hash of the config's debug representation, as 16 hex digits.
pub fn config_fingerprint(cfg: &BenchConfig) -> String {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in format!("{cfg:?}").bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

impl IterationRecord {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("index", Json::Int(self.index as i64));
        j.set("phase", Json::Str(self.phase.name().into()));
        j.set("target_rate", Json::Int(self.target_rate as i64));
        j.set("offered_rate", Json::Num(self.offered_rate));
        j.set("processed_rate", Json::Num(self.processed_rate));
        let mut lat = Json::obj();
        lat.set("p50", Json::Int(self.p50_us as i64));
        lat.set("p95", Json::Int(self.p95_us as i64));
        lat.set("p99", Json::Int(self.p99_us as i64));
        lat.set("mean", Json::Num(self.mean_us));
        j.set("latency_us", lat);
        j.set("backlog", Json::Int(self.backlog as i64));
        j.set("elapsed_us", Json::Int(self.elapsed_micros as i64));
        j.set("sustainable", Json::Bool(self.sustainable));
        j.set(
            "reasons",
            Json::Arr(self.reasons.iter().map(|r| Json::Str(r.clone())).collect()),
        );
        j.set(
            "operators",
            Json::Arr(
                self.operators
                    .iter()
                    .map(|(name, s)| {
                        let mut o = s.to_json();
                        o.set("op", Json::Str(name.clone()));
                        o
                    })
                    .collect(),
            ),
        );
        j
    }

    fn from_json(j: &Json) -> Result<Self, String> {
        let int = |key: &str| -> Result<u64, String> {
            j.get(key)
                .and_then(|v| v.as_i64())
                .map(|v| v.max(0) as u64)
                .ok_or_else(|| format!("iteration: missing int '{key}'"))
        };
        let num = |key: &str| -> Result<f64, String> {
            j.get(key)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("iteration: missing number '{key}'"))
        };
        let lat = j.get("latency_us").ok_or("iteration: missing latency_us")?;
        let lat_int = |key: &str| -> u64 {
            lat.get(key).and_then(|v| v.as_i64()).unwrap_or(0).max(0) as u64
        };
        Ok(IterationRecord {
            index: int("index")? as u32,
            phase: j
                .get("phase")
                .and_then(|v| v.as_str())
                .and_then(Phase::from_name)
                .ok_or("iteration: bad phase")?,
            target_rate: int("target_rate")?,
            offered_rate: num("offered_rate")?,
            processed_rate: num("processed_rate")?,
            p50_us: lat_int("p50"),
            p95_us: lat_int("p95"),
            p99_us: lat_int("p99"),
            mean_us: lat.get("mean").and_then(|v| v.as_f64()).unwrap_or(0.0),
            backlog: int("backlog")?,
            elapsed_micros: int("elapsed_us")?,
            sustainable: j
                .get("sustainable")
                .and_then(|v| v.as_bool())
                .ok_or("iteration: missing sustainable")?,
            reasons: j
                .get("reasons")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|r| r.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
            // Missing in pre-chain reports → empty (back-compat).
            operators: j
                .get("operators")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|o| {
                            o.get("op")
                                .and_then(|v| v.as_str())
                                .map(|name| (name.to_string(), StepStats::from_json(o)))
                        })
                        .collect()
                })
                .unwrap_or_default(),
        })
    }
}

impl ExperimentReport {
    /// The `report.json` document.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("pipeline", Json::Str(self.pipeline.clone()));
        j.set("framework", Json::Str(self.framework.clone()));
        j.set("parallelism", Json::Int(self.parallelism as i64));
        j.set(
            "config_fingerprint",
            Json::Str(self.config_fingerprint.clone()),
        );
        j.set(
            "iterations",
            Json::Arr(self.iterations.iter().map(|i| i.to_json()).collect()),
        );
        let mut mst = Json::obj();
        mst.set("target_rate", Json::Int(self.mst_target_rate as i64));
        mst.set("processed_rate", Json::Num(self.mst_processed_rate));
        j.set("max_sustainable_throughput", mst);
        match self.knee {
            Some((ok, fail)) => {
                let mut k = Json::obj();
                k.set("sustained", Json::Int(ok as i64));
                k.set("failing", Json::Int(fail as i64));
                j.set("knee", k);
            }
            None => {
                j.set("knee", Json::Null);
            }
        }
        j
    }

    /// Parse a `report.json` document back (exact round-trip of
    /// [`Self::to_json`]).
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let s = |key: &str| -> Result<String, String> {
            j.get(key)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| format!("report: missing string '{key}'"))
        };
        let iterations = j
            .get("iterations")
            .and_then(|v| v.as_arr())
            .ok_or("report: missing iterations")?
            .iter()
            .map(IterationRecord::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let mst = j
            .get("max_sustainable_throughput")
            .ok_or("report: missing max_sustainable_throughput")?;
        let knee = match j.get("knee") {
            None | Some(Json::Null) => None,
            Some(k) => Some((
                k.get("sustained")
                    .and_then(|v| v.as_i64())
                    .ok_or("report: knee.sustained")?
                    .max(0) as u64,
                k.get("failing")
                    .and_then(|v| v.as_i64())
                    .ok_or("report: knee.failing")?
                    .max(0) as u64,
            )),
        };
        Ok(ExperimentReport {
            name: s("name")?,
            pipeline: s("pipeline")?,
            framework: s("framework")?,
            parallelism: j
                .get("parallelism")
                .and_then(|v| v.as_i64())
                .ok_or("report: missing parallelism")?
                .clamp(0, u32::MAX as i64) as u32,
            config_fingerprint: s("config_fingerprint")?,
            iterations,
            mst_target_rate: mst
                .get("target_rate")
                .and_then(|v| v.as_i64())
                .ok_or("report: mst.target_rate")?
                .max(0) as u64,
            mst_processed_rate: mst
                .get("processed_rate")
                .and_then(|v| v.as_f64())
                .ok_or("report: mst.processed_rate")?,
            knee,
        })
    }

    /// The `report.md` document: run metadata, the per-iteration table,
    /// and the MST headline.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("# Max-capacity report — {}\n\n", self.name);
        out.push_str(&format!(
            "- pipeline: `{}` / framework: `{}` / parallelism: {}\n",
            self.pipeline, self.framework, self.parallelism
        ));
        out.push_str(&format!(
            "- config fingerprint: `{}`\n\n",
            self.config_fingerprint
        ));
        let rows: Vec<Vec<String>> = self
            .iterations
            .iter()
            .map(|it| {
                vec![
                    it.index.to_string(),
                    it.phase.name().to_string(),
                    fmt_count(it.target_rate as f64),
                    fmt_count(it.offered_rate),
                    fmt_count(it.processed_rate),
                    if it.p50_us > 0 {
                        fmt_micros(it.p50_us)
                    } else {
                        "-".into()
                    },
                    if it.p99_us > 0 {
                        fmt_micros(it.p99_us)
                    } else {
                        "-".into()
                    },
                    if it.sustainable {
                        "yes".into()
                    } else {
                        format!("no — {}", it.reasons.join("; "))
                    },
                ]
            })
            .collect();
        out.push_str(&markdown_table(
            &[
                "#", "phase", "target", "offered", "processed", "p50", "p99", "sustainable",
            ],
            &rows,
        ));
        out.push('\n');
        if self.mst_target_rate > 0 {
            out.push_str(&format!(
                "**Maximum sustainable throughput: {} ev/s** (measured {} ev/s processed)\n",
                fmt_count(self.mst_target_rate as f64),
                fmt_count(self.mst_processed_rate),
            ));
        } else {
            out.push_str("**No sustainable rate found** — every probe failed the predicate.\n");
        }
        match self.knee {
            Some((ok, fail)) => out.push_str(&format!(
                "\nKnee bracket: sustained at {} ev/s, failing at {} ev/s.\n",
                fmt_count(ok as f64),
                fmt_count(fail as f64)
            )),
            // All probes failed: the headline above already says so.
            None if self.mst_target_rate == 0 => {}
            None => out.push_str(
                "\nNo knee found within the iteration budget — the system never saturated; \
                 the MST is a lower bound.\n",
            ),
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample_report() -> ExperimentReport {
        ExperimentReport {
            name: "maxcap-passthrough".into(),
            pipeline: "passthrough".into(),
            framework: "flink".into(),
            parallelism: 4,
            config_fingerprint: "00f1e2d3c4b5a697".into(),
            iterations: vec![
                IterationRecord {
                    index: 0,
                    phase: Phase::Escalate,
                    target_rate: 100_000,
                    offered_rate: 99_800.0,
                    processed_rate: 99_700.0,
                    p50_us: 900,
                    p95_us: 2_000,
                    p99_us: 3_100,
                    mean_us: 1_100.5,
                    backlog: 0,
                    elapsed_micros: 2_000_000,
                    sustainable: true,
                    reasons: vec![],
                    operators: vec![
                        (
                            "cpu_transform".into(),
                            StepStats {
                                events_in: 199_400,
                                events_out: 199_400,
                                alerts: 1_200,
                                hlo_calls: 400,
                                ..StepStats::default()
                            },
                        ),
                        (
                            "emit_events".into(),
                            StepStats {
                                events_in: 199_400,
                                events_out: 199_400,
                                ..StepStats::default()
                            },
                        ),
                    ],
                },
                IterationRecord {
                    index: 1,
                    phase: Phase::Escalate,
                    target_rate: 200_000,
                    offered_rate: 160_000.0,
                    processed_rate: 120_000.0,
                    p50_us: 45_000,
                    p95_us: 0,
                    p99_us: 250_000,
                    mean_us: 80_000.0,
                    backlog: 40_000,
                    elapsed_micros: 2_500_000,
                    sustainable: false,
                    reasons: vec!["fell behind: processed 120000 ev/s < 95% of offered".into()],
                    operators: vec![],
                },
            ],
            mst_target_rate: 100_000,
            mst_processed_rate: 99_700.0,
            knee: Some((100_000, 200_000)),
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let report = sample_report();
        let text = report.to_json().to_pretty();
        let parsed = json::parse(&text).unwrap();
        let back = ExperimentReport::from_json(&parsed).unwrap();
        assert_eq!(back, report);
    }

    #[test]
    fn json_roundtrip_without_knee() {
        let mut report = sample_report();
        report.knee = None;
        report.iterations.truncate(1);
        let back =
            ExperimentReport::from_json(&json::parse(&report.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back, report);
    }

    #[test]
    fn markdown_contains_iterations_and_mst() {
        let md = sample_report().to_markdown();
        assert!(md.contains("# Max-capacity report — maxcap-passthrough"));
        assert!(md.contains("| # | phase | target | offered | processed | p50 | p99 | sustainable |"));
        assert!(md.contains("escalate"));
        assert!(md.contains("fell behind"));
        assert!(md.contains("Maximum sustainable throughput: 100K ev/s"));
        assert!(md.contains("Knee bracket"));
    }

    #[test]
    fn fingerprint_is_stable_and_config_sensitive() {
        let a = BenchConfig::default();
        let mut b = BenchConfig::default();
        assert_eq!(config_fingerprint(&a), config_fingerprint(&b));
        b.engine.parallelism = 16;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        assert_eq!(config_fingerprint(&a).len(), 16);
    }

    #[test]
    fn malformed_report_is_rejected() {
        let j = json::parse("{\"name\": \"x\"}").unwrap();
        assert!(ExperimentReport::from_json(&j).is_err());
    }

    #[test]
    fn pre_chain_reports_without_operator_stats_still_parse() {
        let report = sample_report();
        let mut j = report.to_json();
        // Simulate a report written before the operator-chain redesign.
        if let Json::Arr(iters) = j.get("iterations").cloned().unwrap() {
            let stripped: Vec<Json> = iters
                .into_iter()
                .map(|mut it| {
                    if let Json::Obj(m) = &mut it {
                        m.remove("operators");
                    }
                    it
                })
                .collect();
            j.set("iterations", Json::Arr(stripped));
        }
        let back = ExperimentReport::from_json(&j).unwrap();
        assert!(back.iterations.iter().all(|i| i.operators.is_empty()));
        assert_eq!(back.mst_target_rate, report.mst_target_rate);
    }

    #[test]
    fn operator_stats_roundtrip_in_order() {
        let report = sample_report();
        let back =
            ExperimentReport::from_json(&json::parse(&report.to_json().to_pretty()).unwrap())
                .unwrap();
        let ops = &back.iterations[0].operators;
        assert_eq!(ops.len(), 2);
        assert_eq!(ops[0].0, "cpu_transform");
        assert_eq!(ops[0].1.hlo_calls, 400);
        assert_eq!(ops[1].0, "emit_events");
    }
}
