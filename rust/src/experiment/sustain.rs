//! The sustainability predicate: did one run keep up with its target?
//!
//! Following Karimov et al. ("Benchmarking Distributed Stream Data
//! Processing Systems") and ShuffleBench, a load level is *sustainable*
//! when the system processes it without falling behind: the engine's
//! processed rate tracks the offered rate, no backlog accumulates, and
//! latency neither exceeds a bound nor trends upward across the run.
//! [`SustainPolicy::evaluate`] applies these checks to a finished
//! [`RunSummary`] (plus, optionally, the run's timeline in a
//! [`MetricStore`]) and returns a [`Verdict`] with a reason for every
//! failed check — the reasons land verbatim in the experiment report.

use crate::config::BenchConfig;
use crate::coordinator::RunSummary;
use crate::metrics::{MeasurementPoint, MetricStore};

/// Thresholds the predicate applies; normally resolved from the
/// `experiment:` config section via [`SustainPolicy::from_config`].
#[derive(Clone, Debug)]
pub struct SustainPolicy {
    /// Minimum fraction of the offered rate the engine must process, and
    /// of the target rate the fleet must offer.
    pub sustain_ratio: f64,
    /// p99 end-to-end latency bound in µs; 0 disables the check.
    pub max_p99_micros: u64,
    /// Max multiple by which mean p50 latency may grow from the first to
    /// the second half of the timeline; 0 disables the check.
    pub max_latency_growth: f64,
    /// Timeline samples within this offset of the run start are discarded
    /// before the latency-growth check.
    pub warmup_discard_micros: u64,
    /// Max fraction of processed events that may arrive behind the
    /// watermark (late + dropped, summed across the run's event-time
    /// operators); 0 disables the check.
    pub max_late_fraction: f64,
    /// Max supervised engine restarts before the run is declared
    /// unsustainable; 0 disables the check.
    pub max_restarts: u32,
    /// Availability floor: `1 - downtime/elapsed` must stay at or above
    /// this; 0 disables the check.
    pub min_availability: f64,
}

impl SustainPolicy {
    /// Resolve the policy from a config, applying the inherit rules
    /// (`warmup_discard` 0 → `benchmark.warmup`).
    pub fn from_config(cfg: &BenchConfig) -> Self {
        let x = &cfg.experiment;
        Self {
            sustain_ratio: x.sustain_ratio,
            max_p99_micros: x.max_p99_micros,
            max_latency_growth: x.max_latency_growth,
            warmup_discard_micros: if x.warmup_discard_micros > 0 {
                x.warmup_discard_micros
            } else {
                cfg.bench.warmup_micros
            },
            max_late_fraction: x.max_late_fraction,
            max_restarts: x.max_restarts,
            min_availability: x.min_availability,
        }
    }

    /// Judge one finished run against a target rate.  `store` supplies
    /// the per-interval timeline for the latency-trend check; pass `None`
    /// when no timeline was collected (the check is then skipped).
    pub fn evaluate(
        &self,
        target_rate: u64,
        summary: &RunSummary,
        store: Option<&MetricStore>,
    ) -> Verdict {
        let mut reasons = Vec::new();
        let target = target_rate as f64;

        // The fleet itself must achieve the target; if the generators are
        // the bottleneck there is no point escalating further.
        if summary.offered_rate < self.sustain_ratio * target {
            reasons.push(format!(
                "generator-limited: offered {:.0} ev/s < {:.0}% of target {:.0} ev/s",
                summary.offered_rate,
                self.sustain_ratio * 100.0,
                target
            ));
        }

        // Keep-up: the engine must process what was offered.
        if summary.processed_rate < self.sustain_ratio * summary.offered_rate {
            reasons.push(format!(
                "fell behind: processed {:.0} ev/s < {:.0}% of offered {:.0} ev/s",
                summary.processed_rate,
                self.sustain_ratio * 100.0,
                summary.offered_rate
            ));
        }

        // Backlog: events generated but never processed by run end.
        let backlog = summary.generated.saturating_sub(summary.processed);
        if summary.generated > 0
            && (summary.processed as f64) < self.sustain_ratio * summary.generated as f64
        {
            reasons.push(format!(
                "backlog: {backlog} of {} generated events unprocessed",
                summary.generated
            ));
        }

        // Absolute latency bound.
        if self.max_p99_micros > 0 {
            if let Some(e2e) = summary.latency_at(MeasurementPoint::EndToEnd) {
                if e2e.count > 0 && e2e.p99 > self.max_p99_micros {
                    reasons.push(format!(
                        "p99 latency {}µs > bound {}µs",
                        e2e.p99, self.max_p99_micros
                    ));
                }
            }
        }

        // Event-time health: a system that "keeps up" by letting the
        // watermark race past the data is not sustaining the load — bound
        // the fraction of records arriving behind the watermark.
        if self.max_late_fraction > 0.0 && summary.processed > 0 {
            let late: u64 = summary.operators.iter().map(|(_, s)| s.late_events).sum();
            let dropped: u64 = summary
                .operators
                .iter()
                .map(|(_, s)| s.dropped_events)
                .sum();
            let frac = (late + dropped) as f64 / summary.processed as f64;
            if frac > self.max_late_fraction {
                reasons.push(format!(
                    "late-fraction {:.1}% > bound {:.1}% ({late} late + {dropped} dropped \
                     of {} processed)",
                    frac * 100.0,
                    self.max_late_fraction * 100.0,
                    summary.processed
                ));
            }
        }

        // Resilience SLOs: a run that only "keeps up" by leaning on the
        // supervisor — repeated heal cycles, long stretches with the
        // engine down — is not sustaining the load either.
        if let Some(res) = &summary.resilience {
            if self.max_restarts > 0 && res.restart_count > self.max_restarts as u64 {
                reasons.push(format!(
                    "restart budget: {} supervised restarts > bound {}",
                    res.restart_count, self.max_restarts
                ));
            }
            if self.min_availability > 0.0 && summary.elapsed_micros > 0 {
                let avail = 1.0
                    - (res.downtime_micros.min(summary.elapsed_micros) as f64
                        / summary.elapsed_micros as f64);
                if avail < self.min_availability {
                    reasons.push(format!(
                        "availability {:.4} < floor {:.4} ({}µs down of {}µs)",
                        avail,
                        self.min_availability,
                        res.downtime_micros,
                        summary.elapsed_micros
                    ));
                }
            }
        }

        // Latency trend: a queue that is still filling shows up as p50
        // drifting upward across the run even when throughput looks fine.
        if self.max_latency_growth > 0.0 {
            if let Some(growth) = store.and_then(|s| {
                latency_growth(s, "latency.end_to_end.p50_us", self.warmup_discard_micros)
            }) {
                if growth > self.max_latency_growth {
                    reasons.push(format!(
                        "latency trending up: second-half p50 is {growth:.2}x first half \
                         (bound {:.2}x)",
                        self.max_latency_growth
                    ));
                }
            }
        }

        Verdict {
            sustainable: reasons.is_empty(),
            reasons,
        }
    }
}

/// Outcome of one sustainability evaluation.
#[derive(Clone, Debug, PartialEq)]
pub struct Verdict {
    pub sustainable: bool,
    /// One entry per failed check; empty iff `sustainable`.
    pub reasons: Vec<String>,
}

/// Ratio of the mean of the second half of a series to the mean of the
/// first half, after discarding `warmup_micros` from the series start.
/// `None` when the series is missing or too short to split.
fn latency_growth(store: &MetricStore, series: &str, warmup_micros: u64) -> Option<f64> {
    let s = store.get(series)?;
    let t0 = s.points.first()?.0;
    let s = s.after(t0.saturating_add(warmup_micros));
    if s.len() < 4 {
        return None;
    }
    let mid = s.len() / 2;
    let mean = |pts: &[(u64, f64)]| pts.iter().map(|&(_, v)| v).sum::<f64>() / pts.len() as f64;
    let first = mean(&s.points[..mid]);
    let second = mean(&s.points[mid..]);
    if first <= 0.0 {
        return None;
    }
    Some(second / first)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::histogram::HistogramSummary;

    /// A synthetic summary with the fields the predicate reads.
    fn summary(target: u64, offered: f64, processed_rate: f64, p99: u64) -> RunSummary {
        let generated = (offered * 2.0) as u64;
        let processed = ((processed_rate / offered.max(1.0)) * generated as f64) as u64;
        RunSummary {
            name: format!("probe-{target}"),
            pipeline: "passthrough".into(),
            framework: "flink",
            parallelism: 4,
            generated,
            processed: processed.min(generated),
            emitted: processed.min(generated),
            elapsed_micros: 2_000_000,
            offered_rate: offered,
            processed_rate,
            offered_bytes_rate: offered * 27.0,
            latency: vec![(
                MeasurementPoint::EndToEnd,
                HistogramSummary {
                    count: 1000,
                    mean: p99 as f64 / 3.0,
                    min: 10,
                    p50: p99 / 3,
                    p95: p99 / 2,
                    p99,
                    max: p99 * 2,
                },
            )],
            gc_young_count: 0,
            gc_young_time_micros: 0,
            energy_joules: 0.0,
            parse_failures: 0,
            batches: 1,
            operators: Vec::new(),
            recovery: None,
            quarantined: 0,
            faults: Vec::new(),
            resilience: None,
            transport: None,
        }
    }

    fn policy() -> SustainPolicy {
        SustainPolicy {
            sustain_ratio: 0.95,
            max_p99_micros: 0,
            max_latency_growth: 0.0,
            warmup_discard_micros: 0,
            max_late_fraction: 0.0,
            max_restarts: 0,
            min_availability: 0.0,
        }
    }

    #[test]
    fn keeping_up_is_sustainable() {
        let v = policy().evaluate(100_000, &summary(100_000, 100_000.0, 99_000.0, 5_000), None);
        assert!(v.sustainable, "{:?}", v.reasons);
        assert!(v.reasons.is_empty());
    }

    #[test]
    fn falling_behind_is_not() {
        let v = policy().evaluate(100_000, &summary(100_000, 100_000.0, 60_000.0, 5_000), None);
        assert!(!v.sustainable);
        assert!(
            v.reasons.iter().any(|r| r.contains("fell behind")),
            "{:?}",
            v.reasons
        );
    }

    #[test]
    fn generator_shortfall_is_flagged() {
        let v = policy().evaluate(1_000_000, &summary(1_000_000, 400_000.0, 400_000.0, 5_000), None);
        assert!(!v.sustainable);
        assert!(
            v.reasons.iter().any(|r| r.contains("generator-limited")),
            "{:?}",
            v.reasons
        );
    }

    #[test]
    fn p99_bound_applies_only_when_set() {
        let s = summary(100_000, 100_000.0, 99_000.0, 900_000);
        assert!(policy().evaluate(100_000, &s, None).sustainable);
        let mut p = policy();
        p.max_p99_micros = 100_000;
        let v = p.evaluate(100_000, &s, None);
        assert!(!v.sustainable);
        assert!(v.reasons.iter().any(|r| r.contains("p99")), "{:?}", v.reasons);
    }

    #[test]
    fn latency_trend_detected_from_timeline() {
        let store = MetricStore::new();
        // Warmup noise, then a flat first half and a 3x second half.
        store.append("latency.end_to_end.p50_us", 0, 9_999.0);
        for i in 0..8u64 {
            let v = if i < 4 { 100.0 } else { 300.0 };
            store.append("latency.end_to_end.p50_us", 1_000_000 + i * 1_000_000, v);
        }
        let mut p = policy();
        p.max_latency_growth = 2.0;
        p.warmup_discard_micros = 500_000;
        let good = summary(100_000, 100_000.0, 99_000.0, 5_000);
        let v = p.evaluate(100_000, &good, Some(&store));
        assert!(!v.sustainable);
        assert!(
            v.reasons.iter().any(|r| r.contains("trending up")),
            "{:?}",
            v.reasons
        );
        // Flat series passes.
        let flat = MetricStore::new();
        for i in 0..8u64 {
            flat.append("latency.end_to_end.p50_us", i * 1_000_000, 100.0);
        }
        assert!(p.evaluate(100_000, &good, Some(&flat)).sustainable);
        // Missing series skips the check.
        assert!(p.evaluate(100_000, &good, None).sustainable);
    }

    #[test]
    fn late_fraction_bound_applies_only_when_set() {
        use crate::pipelines::StepStats;
        let mut s = summary(100_000, 100_000.0, 99_000.0, 5_000);
        // Window op with 30% of the processed stream behind the watermark.
        s.operators = vec![(
            "window".to_string(),
            StepStats {
                events_in: s.processed,
                late_events: s.processed / 5,
                dropped_events: s.processed / 10,
                ..StepStats::default()
            },
        )];
        assert!(policy().evaluate(100_000, &s, None).sustainable, "disabled by default");
        let mut p = policy();
        p.max_late_fraction = 0.25;
        let v = p.evaluate(100_000, &s, None);
        assert!(!v.sustainable);
        assert!(
            v.reasons.iter().any(|r| r.contains("late-fraction")),
            "{:?}",
            v.reasons
        );
        // Under the bound: sustainable.
        p.max_late_fraction = 0.40;
        assert!(p.evaluate(100_000, &s, None).sustainable);
    }

    #[test]
    fn restart_budget_and_availability_apply_only_when_set() {
        use crate::engine::ResilienceStats;
        let mut s = summary(100_000, 100_000.0, 99_000.0, 5_000);
        // Two heal cycles, engine down 40% of the run.
        s.resilience = Some(ResilienceStats {
            restart_count: 2,
            downtime_micros: 800_000,
            ..ResilienceStats::default()
        });
        assert!(
            policy().evaluate(100_000, &s, None).sustainable,
            "both checks disabled by default"
        );
        let mut p = policy();
        p.max_restarts = 1;
        let v = p.evaluate(100_000, &s, None);
        assert!(!v.sustainable);
        assert!(
            v.reasons.iter().any(|r| r.contains("restart budget")),
            "{:?}",
            v.reasons
        );
        // Two restarts within a budget of two: fine.
        p.max_restarts = 2;
        assert!(p.evaluate(100_000, &s, None).sustainable);
        // Availability: 1 - 0.8/2.0 = 0.6 < 0.95 floor.
        let mut p = policy();
        p.min_availability = 0.95;
        let v = p.evaluate(100_000, &s, None);
        assert!(!v.sustainable);
        assert!(
            v.reasons.iter().any(|r| r.contains("availability")),
            "{:?}",
            v.reasons
        );
        p.min_availability = 0.5;
        assert!(p.evaluate(100_000, &s, None).sustainable);
        // A fault-free run (no resilience block) passes strict SLOs.
        let clean = summary(100_000, 100_000.0, 99_000.0, 5_000);
        let mut p = policy();
        p.max_restarts = 1;
        p.min_availability = 1.0;
        assert!(p.evaluate(100_000, &clean, None).sustainable);
    }

    #[test]
    fn policy_resolves_inherit_rules_from_config() {
        let mut cfg = BenchConfig::default();
        cfg.bench.warmup_micros = 3_000_000;
        cfg.experiment.warmup_discard_micros = 0;
        assert_eq!(
            SustainPolicy::from_config(&cfg).warmup_discard_micros,
            3_000_000
        );
        cfg.experiment.warmup_discard_micros = 700_000;
        assert_eq!(
            SustainPolicy::from_config(&cfg).warmup_discard_micros,
            700_000
        );
    }
}
