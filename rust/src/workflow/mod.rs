//! Experiment workflow management (paper Sec. 3.1).
//!
//! "The SProBench workflow management system logs every step of an
//! experiment for traceability.  It automates most benchmarking tasks,
//! reduces human error, and ensures consistency across experiments."
//!
//! One master config expands (via [`crate::config::expand_experiments`])
//! into N experiments; the [`WorkflowManager`] gives each a run directory
//! with the resolved config, a step-by-step trace log, the generated
//! sbatch script, and the result/metric exports — then executes them
//! sequentially (wall mode, one machine) or through the SLURM simulator
//! (sim mode, concurrent batch jobs with dependencies).

use std::path::{Path, PathBuf};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::config::Experiment;
use crate::slurm::{sbatch_script, JobRequest, Scheduler};
use crate::util::json::Json;

/// A created run directory with its traceability log.
pub struct RunDir {
    pub path: PathBuf,
    steps: Vec<String>,
}

impl RunDir {
    /// Create `base/<experiment>-<serial>/` with the standard layout.
    pub fn create(base: &Path, experiment: &Experiment) -> std::io::Result<RunDir> {
        let serial = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis())
            .unwrap_or(0);
        let path = base.join(format!("{}-{serial}", experiment.name));
        std::fs::create_dir_all(path.join("metrics"))?;
        let mut dir = RunDir {
            path,
            steps: Vec::new(),
        };
        // Traceability: persist the exact resolved configuration.
        std::fs::write(
            dir.path.join("config.resolved.json"),
            experiment.resolved.to_pretty(),
        )?;
        dir.step("created run directory");
        dir.step("wrote resolved config");
        Ok(dir)
    }

    /// Record one traceability step (appended to `trace.log` on finish).
    pub fn step(&mut self, what: &str) {
        let now = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis())
            .unwrap_or(0);
        self.steps.push(format!("[{now}] {what}"));
    }

    /// Write results + the trace log.
    pub fn finish(&mut self, results: &Json) -> std::io::Result<()> {
        self.step("writing results");
        std::fs::write(self.path.join("results.json"), results.to_pretty())?;
        std::fs::write(self.path.join("trace.log"), self.steps.join("\n") + "\n")?;
        Ok(())
    }

    pub fn metrics_dir(&self) -> PathBuf {
        self.path.join("metrics")
    }
}

/// Outcome of one experiment run.
#[derive(Debug)]
pub struct RunOutcome {
    pub name: String,
    pub dir: PathBuf,
    pub results: Json,
}

/// Drives a list of experiments end to end.
pub struct WorkflowManager {
    base: PathBuf,
}

impl WorkflowManager {
    pub fn new(base: impl AsRef<Path>) -> Self {
        Self {
            base: base.as_ref().to_path_buf(),
        }
    }

    /// Execute every experiment sequentially through `runner`, giving each
    /// a run directory.  The runner returns the experiment's result JSON.
    pub fn run_all<F>(
        &self,
        experiments: &[Experiment],
        mut runner: F,
    ) -> Result<Vec<RunOutcome>, String>
    where
        F: FnMut(&Experiment, &mut RunDir) -> Result<Json, String>,
    {
        let mut outcomes = Vec::with_capacity(experiments.len());
        for exp in experiments {
            let mut dir = RunDir::create(&self.base, exp)
                .map_err(|e| format!("run dir for '{}': {e}", exp.name))?;
            // Emit the sbatch script the batch path would submit.
            let script = sbatch_script(&exp.config, "config.resolved.json");
            std::fs::write(dir.path.join("job.sbatch"), &script)
                .map_err(|e| format!("write sbatch: {e}"))?;
            dir.step("generated sbatch script");
            dir.step("starting benchmark");
            let results = runner(exp, &mut dir)?;
            dir.step("benchmark complete");
            dir.finish(&results).map_err(|e| format!("finish: {e}"))?;
            outcomes.push(RunOutcome {
                name: exp.name.clone(),
                dir: dir.path.clone(),
                results,
            });
        }
        Ok(outcomes)
    }

    /// Batch mode: submit every experiment to the SLURM simulator (with
    /// optional chaining) and return the schedule.  `runtime_of` supplies
    /// each experiment's simulated runtime.
    pub fn submit_batch(
        &self,
        experiments: &[Experiment],
        scheduler: &mut Scheduler,
        chain: bool,
        runtime_of: impl Fn(&Experiment) -> u64,
    ) -> Vec<crate::slurm::JobId> {
        let mut prev = None;
        experiments
            .iter()
            .map(|exp| {
                let req = crate::slurm::resource_request(&exp.config);
                let job = JobRequest {
                    name: exp.name.clone(),
                    nodes: req.nodes,
                    cores_per_node: req.cpus_per_task,
                    mem_per_node_bytes: req.mem_per_node_bytes,
                    time_limit_micros: req.time_limit_micros,
                    runtime_micros: runtime_of(exp),
                    after_ok: if chain { prev } else { None },
                };
                let id = scheduler.submit(job);
                prev = Some(id);
                id
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{expand_experiments, yaml};
    use crate::slurm::{ClusterSpec, JobState};

    fn tmp() -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "sprobench-wf-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    fn experiments(n: usize) -> Vec<Experiment> {
        let mut y = String::from("benchmark:\n  name: wf\nexperiments:\n");
        for i in 0..n {
            y.push_str(&format!("  - name: e{i}\n    engine.parallelism: {}\n", i + 1));
        }
        expand_experiments(&yaml::parse(&y).unwrap()).unwrap()
    }

    #[test]
    fn run_all_creates_complete_run_dirs() {
        let base = tmp();
        let exps = experiments(2);
        let wm = WorkflowManager::new(&base);
        let outcomes = wm
            .run_all(&exps, |exp, dir| {
                dir.step("doing the work");
                let mut j = Json::obj();
                j.set("parallelism", Json::Int(exp.config.engine.parallelism as i64));
                Ok(j)
            })
            .unwrap();
        assert_eq!(outcomes.len(), 2);
        for (i, o) in outcomes.iter().enumerate() {
            assert!(o.dir.join("config.resolved.json").exists());
            assert!(o.dir.join("job.sbatch").exists());
            assert!(o.dir.join("results.json").exists());
            let trace = std::fs::read_to_string(o.dir.join("trace.log")).unwrap();
            assert!(trace.contains("doing the work"));
            assert!(trace.contains("generated sbatch script"));
            assert_eq!(
                o.results.get("parallelism").unwrap().as_i64(),
                Some(i as i64 + 1)
            );
        }
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn runner_failure_propagates() {
        let base = tmp();
        let exps = experiments(1);
        let wm = WorkflowManager::new(&base);
        let err = wm
            .run_all(&exps, |_, _| Err("boom".to_string()))
            .unwrap_err();
        assert_eq!(err, "boom");
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn batch_submission_without_chaining_runs_concurrently() {
        let base = tmp();
        let exps = experiments(3);
        let wm = WorkflowManager::new(&base);
        let mut sched = Scheduler::new(ClusterSpec::tiny(8, 64));
        let ids = wm.submit_batch(&exps, &mut sched, false, |_| 5_000_000);
        sched.run_to_completion();
        for id in ids {
            let j = sched.job(id).unwrap();
            assert_eq!(j.state, JobState::Completed);
            assert_eq!(j.wait_micros(), Some(0), "should run concurrently");
        }
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn batch_submission_with_chaining_serializes() {
        let base = tmp();
        let exps = experiments(3);
        let wm = WorkflowManager::new(&base);
        let mut sched = Scheduler::new(ClusterSpec::tiny(8, 64));
        let ids = wm.submit_batch(&exps, &mut sched, true, |_| 5_000_000);
        let makespan = sched.run_to_completion();
        assert_eq!(makespan, 15_000_000, "chained jobs run back-to-back");
        let starts: Vec<u64> = ids
            .iter()
            .map(|&id| sched.job(id).unwrap().start_micros.unwrap())
            .collect();
        assert!(starts.windows(2).all(|w| w[1] > w[0]));
        let _ = std::fs::remove_dir_all(&base);
    }
}
