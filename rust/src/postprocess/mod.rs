//! Post-processing: aggregate, validate, and render collected metrics.
//!
//! The paper's post-processing unit "aggregates and validates the
//! monitoring data" for offline analysis (Sec. 3).  Here:
//!
//! * [`report`] — ASCII/Markdown tables, plots and CSV emitters used by
//!   the CLI `report` and `max-capacity` commands, the examples, and
//!   every bench target.
//! * [`validate`] — consistency checks over a finished run's results
//!   (conservation of events, sane latencies, monotone counters).

pub mod report;
pub mod validate;

pub use report::{ascii_plot, ascii_table, csv_from_rows, markdown_table, operator_stats_table};
pub use validate::{validate_results, Violation};
