//! Result validation: the consistency checks the post-processing unit
//! applies before a run's numbers are trusted (the paper cites ESPBench's
//! result-validation emphasis and adopts it).

use crate::util::json::Json;

/// One failed validation check.
#[derive(Clone, Debug, PartialEq)]
pub struct Violation {
    pub check: &'static str,
    pub detail: String,
}

fn get_f(j: &Json, path: &[&str]) -> Option<f64> {
    j.path(path).and_then(|v| v.as_f64())
}

/// Validate a run's `results.json` document.
///
/// Expected shape (produced by the coordinator):
/// ```json
/// {
///   "pipeline": "cpu", "events": {"generated": N, "processed": N, "emitted": N},
///   "latency_us": {"broker_in": {...}, "end_to_end": {"p50": x, "p99": y, ...}},
///   "throughput": {"offered": r, "processed": r},
///   "gc": {"young_count": n, "young_time_ms": t},
///   "energy": {"joules": e}
/// }
/// ```
pub fn validate_results(results: &Json) -> Vec<Violation> {
    let mut v = Vec::new();
    let pipeline = results
        .get("pipeline")
        .and_then(|p| p.as_str())
        .unwrap_or("");

    let generated = get_f(results, &["events", "generated"]).unwrap_or(-1.0);
    let processed = get_f(results, &["events", "processed"]).unwrap_or(-1.0);
    let emitted = get_f(results, &["events", "emitted"]).unwrap_or(-1.0);

    if generated < 0.0 || processed < 0.0 || emitted < 0.0 {
        v.push(Violation {
            check: "counters-present",
            detail: "missing events.{generated,processed,emitted}".into(),
        });
        return v;
    }
    if generated == 0.0 {
        v.push(Violation {
            check: "nonempty-run",
            detail: "no events were generated".into(),
        });
    }
    if processed > generated {
        v.push(Violation {
            check: "conservation",
            detail: format!("processed {processed} > generated {generated}"),
        });
    }
    // Quarantine-aware conservation: `processed` counts only clean
    // records, so the quarantined ones must still fit under `generated`.
    let quarantined = get_f(results, &["events", "quarantined"]).unwrap_or(0.0);
    if quarantined > 0.0 && processed + quarantined > generated {
        v.push(Violation {
            check: "conservation",
            detail: format!(
                "processed {processed} + quarantined {quarantined} > generated {generated}"
            ),
        });
    }
    // Pass-through and CPU pipelines forward 1:1; processed events that
    // vanished without being emitted indicate loss.
    if (pipeline == "passthrough" || pipeline == "cpu") && emitted < processed {
        v.push(Violation {
            check: "forwarding",
            detail: format!("{pipeline}: emitted {emitted} < processed {processed}"),
        });
    }
    // Latency sanity: p50 <= p99, positive, and present for e2e.
    match (
        get_f(results, &["latency_us", "end_to_end", "p50"]),
        get_f(results, &["latency_us", "end_to_end", "p99"]),
    ) {
        (Some(p50), Some(p99)) => {
            if p50 > p99 {
                v.push(Violation {
                    check: "latency-order",
                    detail: format!("e2e p50 {p50} > p99 {p99}"),
                });
            }
            if p50 < 0.0 {
                v.push(Violation {
                    check: "latency-positive",
                    detail: format!("negative p50 {p50}"),
                });
            }
        }
        _ if processed > 0.0 => v.push(Violation {
            check: "latency-present",
            detail: "processed events but no end-to-end latency recorded".into(),
        }),
        _ => {}
    }
    // GC counters are cumulative → non-negative.
    if let Some(c) = get_f(results, &["gc", "young_count"]) {
        if c < 0.0 {
            v.push(Violation {
                check: "gc-nonnegative",
                detail: format!("young_count {c}"),
            });
        }
    }
    if let Some(j) = get_f(results, &["energy", "joules"]) {
        if !(j >= 0.0) || j.is_nan() {
            v.push(Violation {
                check: "energy-sane",
                detail: format!("joules {j}"),
            });
        }
    }
    // Kill-and-restore runs carry a `recovery` block; its accounting must
    // be internally consistent with the event counters.
    if let Some(rec) = results.get("recovery") {
        let replayed = get_f(results, &["recovery", "replayed_records"]).unwrap_or(-1.0);
        let rt = get_f(results, &["recovery", "recovery_time_us"]).unwrap_or(-1.0);
        let ckpts = get_f(results, &["recovery", "checkpoints"]).unwrap_or(-1.0);
        if replayed < 0.0 || rt < 0.0 || ckpts < 0.0 {
            v.push(Violation {
                check: "recovery-counters-present",
                detail: "missing recovery.{replayed_records,recovery_time_us,checkpoints}".into(),
            });
        }
        if replayed > generated {
            v.push(Violation {
                check: "recovery-replay-bound",
                detail: format!("replayed {replayed} > generated {generated}"),
            });
        }
        match rec.get("cold_start").and_then(|c| c.as_bool()) {
            None => v.push(Violation {
                check: "recovery-cold-start-present",
                detail: "recovery.cold_start missing or not a bool".into(),
            }),
            Some(false) => {
                // A warm restore names the checkpoint it came from and
                // implies at least one checkpoint was ever committed.
                let epoch = get_f(results, &["recovery", "restored_epoch"]).unwrap_or(0.0);
                if epoch < 1.0 {
                    v.push(Violation {
                        check: "recovery-restore-epoch",
                        detail: format!("warm restore but restored_epoch {epoch}"),
                    });
                }
                if ckpts < 1.0 {
                    v.push(Violation {
                        check: "recovery-checkpointed",
                        detail: format!("warm restore but checkpoints {ckpts}"),
                    });
                }
            }
            Some(true) => {}
        }
        // A fault that forced replay cannot have recovered in zero time.
        if replayed > 0.0 && rt == 0.0 {
            v.push(Violation {
                check: "recovery-time-nonzero",
                detail: format!("replayed {replayed} records in 0 µs"),
            });
        }
    }
    // Supervised runs carry `resilience` + `faults[]`; the fault
    // timelines and the aggregate counters must agree with each other
    // and with the quarantine counter.
    if let Some(res) = results.get("resilience") {
        let injected = get_f(results, &["resilience", "injected"]).unwrap_or(-1.0);
        let detected = get_f(results, &["resilience", "detected"]).unwrap_or(-1.0);
        let healed = get_f(results, &["resilience", "healed"]).unwrap_or(-1.0);
        let restarts = get_f(results, &["resilience", "restart_count"]).unwrap_or(-1.0);
        let cold = get_f(results, &["resilience", "cold_starts"]).unwrap_or(-1.0);
        if injected < 0.0 || detected < 0.0 || healed < 0.0 || restarts < 0.0 {
            v.push(Violation {
                check: "resilience-counters-present",
                detail: "missing resilience.{injected,detected,healed,restart_count}".into(),
            });
        }
        if detected > injected || healed > injected {
            v.push(Violation {
                check: "resilience-causality",
                detail: format!(
                    "detected {detected} / healed {healed} exceed injected {injected}"
                ),
            });
        }
        if cold > restarts {
            v.push(Violation {
                check: "resilience-cold-starts",
                detail: format!("cold_starts {cold} > restart_count {restarts}"),
            });
        }
        let poison = res.get("poison_records").and_then(|p| p.as_f64()).unwrap_or(0.0);
        if poison != quarantined {
            v.push(Violation {
                check: "quarantine-consistent",
                detail: format!(
                    "resilience.poison_records {poison} != events.quarantined {quarantined}"
                ),
            });
        }
    }
    if let Some(faults) = results.get("faults").and_then(|f| f.as_arr()) {
        for (i, f) in faults.iter().enumerate() {
            let injected = f.get("injected").and_then(|b| b.as_bool()).unwrap_or(false);
            let detected = f.get("detected").and_then(|b| b.as_bool()).unwrap_or(false);
            let healed = f.get("healed").and_then(|b| b.as_bool()).unwrap_or(false);
            if (detected || healed) && !injected {
                v.push(Violation {
                    check: "fault-causality",
                    detail: format!("faults[{i}] detected/healed but never injected"),
                });
            }
            let detect = f.get("detect_us").and_then(|x| x.as_f64()).unwrap_or(0.0);
            let mttr = f.get("mttr_us").and_then(|x| x.as_f64()).unwrap_or(0.0);
            if detected && healed && mttr < detect {
                v.push(Violation {
                    check: "fault-slo-order",
                    detail: format!("faults[{i}] mttr_us {mttr} < detect_us {detect}"),
                });
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn good() -> Json {
        parse(
            r#"{
            "pipeline": "cpu",
            "events": {"generated": 1000, "processed": 1000, "emitted": 1000},
            "latency_us": {"end_to_end": {"p50": 900, "p99": 4000}},
            "gc": {"young_count": 4},
            "energy": {"joules": 120.5}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn clean_run_validates() {
        assert!(validate_results(&good()).is_empty());
    }

    #[test]
    fn detects_event_loss_on_forwarding_pipelines() {
        let mut j = good();
        crate::config::overlay(&mut j, "events.emitted", Json::Int(900));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "forwarding"), "{v:?}");
    }

    #[test]
    fn mem_pipeline_may_emit_fewer() {
        let mut j = good();
        crate::config::overlay(&mut j, "pipeline", Json::Str("mem".into()));
        crate::config::overlay(&mut j, "events.emitted", Json::Int(64));
        assert!(validate_results(&j).is_empty());
    }

    #[test]
    fn detects_impossible_conservation() {
        let mut j = good();
        crate::config::overlay(&mut j, "events.processed", Json::Int(2000));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "conservation"));
    }

    #[test]
    fn detects_inverted_percentiles() {
        let mut j = good();
        crate::config::overlay(&mut j, "latency_us.end_to_end.p50", Json::Int(9000));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "latency-order"));
    }

    #[test]
    fn missing_counters_is_fatal() {
        let j = parse(r#"{"pipeline": "cpu"}"#).unwrap();
        let v = validate_results(&j);
        assert_eq!(v[0].check, "counters-present");
    }

    fn good_recovery() -> Json {
        let mut j = good();
        let rec = parse(
            r#"{
            "recovery_time_us": 1500, "replayed_records": 120,
            "restored_epoch": 3, "cold_start": false,
            "corrupt_skipped": 0, "checkpoints": 4,
            "checkpoint_bytes": 2048, "checkpoint_write_us": 90
        }"#,
        )
        .unwrap();
        j.set("recovery", rec);
        j
    }

    #[test]
    fn recovery_block_validates_when_consistent() {
        assert!(validate_results(&good_recovery()).is_empty());
    }

    #[test]
    fn detects_replay_exceeding_generated() {
        let mut j = good_recovery();
        crate::config::overlay(&mut j, "recovery.replayed_records", Json::Int(5000));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "recovery-replay-bound"), "{v:?}");
    }

    #[test]
    fn detects_warm_restore_without_checkpoint_evidence() {
        let mut j = good_recovery();
        crate::config::overlay(&mut j, "recovery.restored_epoch", Json::Int(0));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "recovery-restore-epoch"), "{v:?}");
        let mut j = good_recovery();
        crate::config::overlay(&mut j, "recovery.checkpoints", Json::Int(0));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "recovery-checkpointed"), "{v:?}");
    }

    #[test]
    fn detects_instant_recovery_with_replay() {
        let mut j = good_recovery();
        crate::config::overlay(&mut j, "recovery.recovery_time_us", Json::Int(0));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "recovery-time-nonzero"), "{v:?}");
    }

    #[test]
    fn fault_free_run_needs_no_recovery_block() {
        // `good()` has no recovery block and must stay clean (covered by
        // clean_run_validates) — and a cold start with zero replay is
        // also legitimate (nothing survived, nothing re-read).
        let mut j = good_recovery();
        crate::config::overlay(&mut j, "recovery.cold_start", Json::Bool(true));
        crate::config::overlay(&mut j, "recovery.restored_epoch", Json::Int(0));
        crate::config::overlay(&mut j, "recovery.checkpoints", Json::Int(0));
        assert!(validate_results(&j).is_empty());
    }

    fn good_resilience() -> Json {
        let mut j = good();
        crate::config::overlay(&mut j, "events.quarantined", Json::Int(0));
        let res = parse(
            r#"{
            "injected": 2, "detected": 2, "healed": 2,
            "restart_count": 2, "cold_starts": 0,
            "downtime_us": 600000, "detect_us": 1000, "mttr_us": 300000,
            "poison_records": 0, "dead_letter_sample": []
        }"#,
        )
        .unwrap();
        j.set("resilience", res);
        let faults = parse(
            r#"[
            {"kind": "kill_task", "target": "task 0", "at_us": 500000,
             "duration_us": 0, "injected": true, "detected": true,
             "healed": true, "detect_us": 1000, "mttr_us": 280000},
            {"kind": "hang_task", "target": "task 1", "at_us": 2000000,
             "duration_us": 400000, "injected": true, "detected": true,
             "healed": true, "detect_us": 250000, "mttr_us": 320000}
        ]"#,
        )
        .unwrap();
        j.set("faults", faults);
        j
    }

    #[test]
    fn supervised_run_blocks_validate_when_consistent() {
        assert!(validate_results(&good_resilience()).is_empty());
    }

    #[test]
    fn detects_quarantine_breaking_conservation() {
        let mut j = good();
        // 1000 generated, 1000 processed — quarantined records must have
        // been subtracted from processed, so 50 more breaks conservation.
        crate::config::overlay(&mut j, "events.quarantined", Json::Int(50));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "conservation"), "{v:?}");
        // Subtracted correctly: clean.
        crate::config::overlay(&mut j, "events.processed", Json::Int(950));
        crate::config::overlay(&mut j, "events.emitted", Json::Int(950));
        let mut jr = good_resilience();
        crate::config::overlay(&mut jr, "events.quarantined", Json::Int(50));
        crate::config::overlay(&mut jr, "events.processed", Json::Int(950));
        crate::config::overlay(&mut jr, "events.emitted", Json::Int(950));
        crate::config::overlay(&mut jr, "resilience.poison_records", Json::Int(50));
        assert!(validate_results(&jr).is_empty());
    }

    #[test]
    fn detects_fault_causality_and_slo_order() {
        let mut j = good_resilience();
        crate::config::overlay(&mut j, "resilience.healed", Json::Int(3));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "resilience-causality"), "{v:?}");
        let mut j = good_resilience();
        crate::config::overlay(&mut j, "resilience.cold_starts", Json::Int(5));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "resilience-cold-starts"), "{v:?}");
        let mut j = good_resilience();
        crate::config::overlay(&mut j, "resilience.poison_records", Json::Int(9));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "quarantine-consistent"), "{v:?}");
        // A healed fault that was never injected is incoherent.
        let mut j = good_resilience();
        let mut fs = j.get("faults").and_then(|f| f.as_arr()).unwrap().to_vec();
        fs[0].set("injected", Json::Bool(false));
        j.set("faults", Json::Arr(fs));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "fault-causality"), "{v:?}");
        // Healing cannot be faster than detecting.
        let mut j = good_resilience();
        let mut fs = j.get("faults").and_then(|f| f.as_arr()).unwrap().to_vec();
        fs[1].set("mttr_us", Json::Int(100));
        j.set("faults", Json::Arr(fs));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "fault-slo-order"), "{v:?}");
    }

    #[test]
    fn empty_run_is_flagged() {
        let mut j = good();
        crate::config::overlay(&mut j, "events.generated", Json::Int(0));
        crate::config::overlay(&mut j, "events.processed", Json::Int(0));
        crate::config::overlay(&mut j, "events.emitted", Json::Int(0));
        let v = validate_results(&j);
        assert!(v.iter().any(|x| x.check == "nonempty-run"));
    }
}
