//! ASCII/Markdown table rendering, ASCII plots, and CSV emission.

use crate::pipelines::StepStats;

/// Render an aligned ASCII table.
pub fn ascii_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let cols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(cols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let sep: String = {
        let mut s = String::from("+");
        for w in &widths {
            s.push_str(&"-".repeat(w + 2));
            s.push('+');
        }
        s.push('\n');
        s
    };
    let mut out = String::new();
    out.push_str(&sep);
    out.push('|');
    for (h, w) in headers.iter().zip(&widths) {
        out.push_str(&format!(" {h:<w$} |"));
    }
    out.push('\n');
    out.push_str(&sep);
    for row in rows {
        out.push('|');
        for i in 0..cols {
            let empty = String::new();
            let cell = row.get(i).unwrap_or(&empty);
            out.push_str(&format!(" {cell:<w$} |", w = widths[i]));
        }
        out.push('\n');
    }
    out.push_str(&sep);
    out
}

/// Render the per-operator stats breakdown of a run (chain order), as
/// printed under the CLI run summary.  The event-time columns (late,
/// dropped, watermark lag) are all zero for processing-time chains, and
/// the exchange columns (rows/bytes routed, worst queue wait) are only
/// non-zero on the `exchange` boundary entries of staged chains.
pub fn operator_stats_table(ops: &[(String, StepStats)]) -> String {
    let rows: Vec<Vec<String>> = ops
        .iter()
        .map(|(name, s)| {
            vec![
                name.clone(),
                s.events_in.to_string(),
                s.events_out.to_string(),
                s.alerts.to_string(),
                s.hlo_calls.to_string(),
                s.window_emits.to_string(),
                s.parse_failures.to_string(),
                s.late_events.to_string(),
                s.dropped_events.to_string(),
                s.watermark_lag_micros.to_string(),
                s.exchange_records.to_string(),
                s.exchange_bytes.to_string(),
                s.exchange_wait_micros.to_string(),
            ]
        })
        .collect();
    ascii_table(
        &[
            "operator",
            "in",
            "out",
            "alerts",
            "hlo",
            "win_emits",
            "parse_fail",
            "late",
            "dropped",
            "wm_lag_us",
            "xchg_rows",
            "xchg_bytes",
            "xchg_wait_us",
        ],
        &rows,
    )
}

/// Render a GitHub-flavored Markdown table (used by the max-capacity
/// experiment reports; cells are pipe-escaped).
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let esc = |s: &str| s.replace('|', "\\|");
    let mut out = String::from("|");
    for h in headers {
        out.push_str(&format!(" {} |", esc(h)));
    }
    out.push_str("\n|");
    for _ in headers {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push('|');
        for i in 0..headers.len() {
            let empty = String::new();
            let cell = row.get(i).unwrap_or(&empty);
            out.push_str(&format!(" {} |", esc(cell)));
        }
        out.push('\n');
    }
    out
}

/// Render a single series as an ASCII line plot (x ascending).
pub fn ascii_plot(series: &[(f64, f64)], width: usize, height: usize, title: &str) -> String {
    let mut out = format!("{title}\n");
    if series.is_empty() || width < 8 || height < 2 {
        out.push_str("(no data)\n");
        return out;
    }
    let (xmin, xmax) = series
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &(x, _)| {
            (a.min(x), b.max(x))
        });
    let (ymin, ymax) = series
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(a, b), &(_, y)| {
            (a.min(y), b.max(y))
        });
    let xspan = (xmax - xmin).max(1e-12);
    let yspan = (ymax - ymin).max(1e-12);
    let mut grid = vec![vec![b' '; width]; height];
    for &(x, y) in series {
        let cx = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
        let cy = (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
        grid[height - 1 - cy][cx] = b'*';
    }
    out.push_str(&format!("{ymax:>12.3} ┤\n"));
    for row in grid {
        out.push_str("             │");
        out.push_str(std::str::from_utf8(&row).expect("ascii"));
        out.push('\n');
    }
    out.push_str(&format!("{ymin:>12.3} ┤"));
    out.push_str(&"─".repeat(width));
    out.push('\n');
    out.push_str(&format!(
        "             {xmin:<.3} … {xmax:<.3}\n"
    ));
    out
}

/// Emit rows as CSV with a header line.
pub fn csv_from_rows(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = headers.join(",");
    out.push('\n');
    for row in rows {
        out.push_str(
            &row.iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_aligns_columns() {
        let t = ascii_table(
            &["name", "rate"],
            &[
                vec!["sprobench".into(), "40M".into()],
                vec!["ysb".into(), "0.2M".into()],
            ],
        );
        assert!(t.contains("| sprobench | 40M  |"));
        assert!(t.contains("| ysb       | 0.2M |"));
        let lines: Vec<&str> = t.lines().collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "ragged table:\n{t}");
    }

    #[test]
    fn operator_table_lists_chain_order() {
        let ops = vec![
            (
                "filter".to_string(),
                StepStats {
                    events_in: 100,
                    events_out: 60,
                    ..StepStats::default()
                },
            ),
            (
                "exchange".to_string(),
                StepStats {
                    events_in: 60,
                    events_out: 60,
                    exchange_records: 60,
                    exchange_bytes: 1_440,
                    exchange_wait_micros: 330,
                    ..StepStats::default()
                },
            ),
            (
                "window".to_string(),
                StepStats {
                    events_in: 60,
                    window_emits: 4,
                    late_events: 7,
                    dropped_events: 3,
                    watermark_lag_micros: 1_250,
                    ..StepStats::default()
                },
            ),
        ];
        let t = operator_stats_table(&ops);
        let filter_line = t.lines().position(|l| l.contains("filter")).unwrap();
        let window_line = t.lines().position(|l| l.contains("window")).unwrap();
        assert!(filter_line < window_line, "chain order must be preserved:\n{t}");
        assert!(t.contains("100"));
        assert!(t.contains("win_emits"));
        // Event-time accounting columns.
        assert!(t.contains("late"));
        assert!(t.contains("dropped"));
        assert!(t.contains("wm_lag_us"));
        assert!(t.contains("1250"));
        // Exchange columns.
        assert!(t.contains("xchg_rows"));
        assert!(t.contains("xchg_bytes"));
        assert!(t.contains("xchg_wait_us"));
        assert!(t.contains("1440"));
        assert!(t.contains("330"));
    }

    #[test]
    fn markdown_table_shape_and_escaping() {
        let t = markdown_table(
            &["rate", "verdict"],
            &[
                vec!["1M".into(), "ok".into()],
                vec!["2M".into(), "p99 | too high".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines[0], "| rate | verdict |");
        assert_eq!(lines[1], "|---|---|");
        assert_eq!(lines[2], "| 1M | ok |");
        assert!(lines[3].contains("p99 \\| too high"));
    }

    #[test]
    fn plot_renders_points() {
        let series: Vec<(f64, f64)> = (0..20).map(|i| (i as f64, (i * i) as f64)).collect();
        let p = ascii_plot(&series, 40, 10, "growth");
        assert!(p.starts_with("growth\n"));
        assert!(p.contains('*'));
    }

    #[test]
    fn plot_empty_series_is_graceful() {
        assert!(ascii_plot(&[], 40, 10, "t").contains("(no data)"));
    }

    #[test]
    fn csv_escapes_commas_and_quotes() {
        let csv = csv_from_rows(
            &["a", "b"],
            &[vec!["x,y".into(), "say \"hi\"".into()]],
        );
        assert_eq!(csv, "a,b\n\"x,y\",\"say \"\"hi\"\"\"\n");
    }
}
