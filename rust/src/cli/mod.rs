//! Command-line interface (hand-rolled; clap is not vendored offline).
//!
//! The paper's CLI orchestrates "all components, setting up frameworks,
//! compiling the resources and performing the benchmarks", on local
//! machines and SLURM clusters, interactive and batch (Sec. 3).
//!
//! ```text
//! sprobench run          --config <file> [--experiment <name>] [--out <dir>] [--pipeline-spec <file>]
//! sprobench max-capacity --config <file> [--experiment <name>] [--out <dir>] [--pipeline-spec <file>]
//! sprobench sbatch       --config <file> [--simulate] [--chain]
//! sprobench report       --run <dir>
//! sprobench baselines    [--events <n>]
//! sprobench analyze      [<pass>…|--all] [--root <dir>] [--json <file>] [--verbose] [--bless]
//! sprobench list         --config <file>
//! sprobench version | help
//! ```

use std::path::{Path, PathBuf};

use crate::config::{self, BenchConfig, ExecMode, Experiment};
use crate::coordinator::{run_recovery, simrun};
use crate::experiment::MaxCapacityDriver;
use crate::postprocess::{ascii_table, operator_stats_table, validate_results};
use crate::runtime::RuntimeFactory;
use crate::slurm::{ClusterSpec, Scheduler};
use crate::util::json::{self, Json};
use crate::util::units::{fmt_count, fmt_micros, fmt_rate_bytes};
use crate::workflow::WorkflowManager;

/// Entry point; returns the process exit code.
pub fn main() -> i32 {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

/// Parsed flag set: `--key value` pairs + bare flags.
struct Flags {
    pairs: Vec<(String, String)>,
    bare: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> Flags {
        let mut pairs = Vec::new();
        let mut bare = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    pairs.push((key.to_string(), args[i + 1].clone()));
                    i += 2;
                } else {
                    bare.push(key.to_string());
                    i += 1;
                }
            } else {
                bare.push(a.clone());
                i += 1;
            }
        }
        Flags { pairs, bare }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.bare.iter().any(|b| b == key)
    }
}

pub fn dispatch(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        println!("{}", usage());
        return Ok(());
    };
    let flags = Flags::parse(&args[1..]);
    match cmd {
        "run" => cmd_run(&flags),
        "worker" => cmd_worker(&flags),
        "max-capacity" => cmd_max_capacity(&flags),
        "sbatch" => cmd_sbatch(&flags),
        "report" => cmd_report(&flags),
        "baselines" => cmd_baselines(&flags),
        "analyze" => cmd_analyze(&flags),
        "list" => cmd_list(&flags),
        "version" => {
            println!("sprobench {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage())),
    }
}

fn usage() -> &'static str {
    "SProBench — stream processing benchmark for HPC infrastructure

USAGE:
  sprobench run          --config <file> [--experiment <name>] [--out <dir>] [--pipeline-spec <file>]
  sprobench worker       --role <broker|generator|engine> --driver <host:port> [--bind <host:port>]
  sprobench max-capacity --config <file> [--experiment <name>] [--out <dir>] [--pipeline-spec <file>]
  sprobench sbatch       --config <file> [--simulate] [--chain]
  sprobench report       --run <dir>
  sprobench baselines    [--events <n>]
  sprobench analyze      [<pass>…|--all] [--root <dir>] [--json <file>] [--sarif <file>] [--changed-since <rev>] [--verbose] [--bless]
  sprobench list         --config <file>
  sprobench version | help

The config file is the single master control point (YAML); its
`experiments:` list expands into one run per entry.  `max-capacity`
escalates the offered load until the sustainability predicate fails
(see the `experiment:` config section) and writes report.json +
report.md with the maximum sustainable throughput.

With `cluster.transport: tcp` in the config, `run` becomes the driver
of a multi-process run: it launches (or, on SLURM, is joined by) one
broker, one engine, and `cluster.generators` generator worker
processes, merges their result fragments into results.json, and adds a
`transport` block with the wire-level counters.  `worker` is the role
main those processes execute; it is normally started by the driver or
by the generated sbatch script, not by hand.

Pipelines are operator chains: configure `engine.pipeline` with a kind
(passthrough | cpu | mem | fused) or a declarative `ops:` spec
(filter/map/keyby/window/topk/emit/custom); `--pipeline-spec <file>`
overrides every selected experiment with the `ops:` list from <file>.

`analyze` runs the in-repo static-analysis passes (tests, panics,
locks, locks2, schema, structs, grammar, protocol, channels,
conservation) over the source tree at --root (default: the working
directory): pass names select a subset, no names or --all runs
everything, --bless regenerates the panic-path baseline, and the
findings are written to analysis_report.json (--json overrides the
path).  --sarif <file> additionally emits SARIF 2.1.0 for code-scanning
upload; --changed-since <rev> demotes errors in files untouched since
the git revision to [pre-existing] notes, so CI can annotate a PR with
only the findings it introduced.  Exit is nonzero on any error-severity
finding — CI runs `analyze --all` as a gate."
}

fn load_experiments(flags: &Flags) -> Result<Vec<Experiment>, String> {
    let path = flags.get("config").ok_or("--config <file> is required")?;
    let mut exps = config::load_file(Path::new(path))?;
    if let Some(name) = flags.get("experiment") {
        exps.retain(|e| e.name == name);
        if exps.is_empty() {
            return Err(format!("no experiment named '{name}' in {path}"));
        }
    }
    apply_pipeline_spec_flag(flags, &mut exps)?;
    // The CLI cannot supply an OperatorRegistry, so specs referencing
    // custom (or misspelled) operator names must fail here — before a run
    // launches — not inside the first engine task.
    for exp in &exps {
        if let Some(spec) = &exp.config.engine.pipeline_spec {
            let custom = spec.custom_op_names();
            if !custom.is_empty() {
                return Err(format!(
                    "{}: pipeline spec uses operator(s) [{}] that are not built-ins — \
                     the CLI cannot resolve custom operators (use the \
                     StepFactory::with_registry API; see examples/custom_pipeline.rs). \
                     If this is a typo, the built-ins are: forward, filter, map, \
                     cpu_transform, keyby, window, topk, emit, emit_events, \
                     emit_aggregates.",
                    exp.name,
                    custom.join(", ")
                ));
            }
        }
    }
    Ok(exps)
}

/// `--pipeline-spec <file>`: override every selected experiment's pipeline
/// with the operator-chain spec in <file> (an `ops:` document or bare
/// list, same grammar as `engine.pipeline.ops`).
fn apply_pipeline_spec_flag(flags: &Flags, exps: &mut [Experiment]) -> Result<(), String> {
    let Some(path) = flags.get("pipeline-spec") else {
        return Ok(());
    };
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read pipeline spec {path}: {e}"))?;
    let doc = config::yaml::parse(&text).map_err(|e| e.to_string())?;
    let spec = config::parse_pipeline_spec(&doc).map_err(|e| e.to_string())?;
    for exp in exps.iter_mut() {
        exp.config.engine.pipeline_spec = Some(spec.clone());
        exp.config
            .validate()
            .map_err(|e| format!("{}: {e}", exp.name))?;
    }
    Ok(())
}

/// Execute one resolved config through the mode-appropriate entry point
/// (shared by `run` and `max-capacity`).
fn run_once(
    cfg: &BenchConfig,
    rtf: &RuntimeFactory,
) -> Result<
    (
        crate::coordinator::RunSummary,
        std::sync::Arc<crate::metrics::MetricStore>,
    ),
    String,
> {
    match cfg.bench.mode {
        // `run_recovery` degrades to a plain wall run when no fault plan
        // is configured, so wall mode always routes through it.
        ExecMode::Wall => run_recovery(cfg, cfg.engine.use_hlo.then(|| rtf.clone())),
        ExecMode::Sim => Ok(simrun::run_sim(cfg, &simrun::SimModel::default())),
    }
}

fn cmd_run(flags: &Flags) -> Result<(), String> {
    let exps = load_experiments(flags)?;
    let out_dir = PathBuf::from(flags.get("out").unwrap_or("runs"));
    let wm = WorkflowManager::new(&out_dir);
    let rtf = RuntimeFactory::default_dir();
    let outcomes = wm.run_all(&exps, |exp, dir| {
        dir.step(&format!(
            "mode={:?} pipeline={} parallelism={}",
            exp.config.bench.mode,
            exp.config.engine.pipeline_label(),
            exp.config.engine.parallelism
        ));
        if exp.config.cluster.transport == config::TransportMode::Tcp {
            dir.step("distributed run: driver + broker/engine/generator workers over tcp");
            let results = crate::net::runner::run_driver(&exp.config, &exp.resolved)?;
            let violations = validate_results(&results);
            if !violations.is_empty() {
                dir.step(&format!("VALIDATION FAILED: {violations:?}"));
                return Err(format!("{}: validation failed: {violations:?}", exp.name));
            }
            dir.step("validation passed");
            print_distributed_summary(&results);
            return Ok(results);
        }
        let (summary, store) = run_once(&exp.config, &rtf)?;
        dir.step("exporting metrics");
        std::fs::write(dir.metrics_dir().join("series.json"), store.to_json().to_pretty())
            .map_err(|e| format!("write metrics: {e}"))?;
        let results = summary.to_json();
        let violations = validate_results(&results);
        if !violations.is_empty() {
            dir.step(&format!("VALIDATION FAILED: {violations:?}"));
            return Err(format!("{}: validation failed: {violations:?}", exp.name));
        }
        dir.step("validation passed");
        print_summary(&summary);
        Ok(results)
    })?;
    println!("\n{} run(s) complete; results under {}", outcomes.len(), out_dir.display());
    Ok(())
}

/// Role main for one distributed worker process (started by the driver
/// or by the generated sbatch script).
fn cmd_worker(flags: &Flags) -> Result<(), String> {
    let role = flags
        .get("role")
        .ok_or("--role <broker|generator|engine> is required")?;
    let driver = flags.get("driver").ok_or("--driver <host:port> is required")?;
    crate::net::runner::run_worker(role, driver, flags.get("bind"))
}

/// Condensed table for a merged distributed-run document (there is no
/// in-process `RunSummary` to print — the driver only sees fragments).
fn print_distributed_summary(results: &Json) {
    let gi = |path: &[&str]| {
        results
            .path(path)
            .and_then(|v| v.as_i64())
            .unwrap_or(0)
    };
    let gf = |path: &[&str]| {
        results
            .path(path)
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0)
    };
    let rows = vec![
        vec![
            "events gen/proc/emit".into(),
            format!(
                "{} / {} / {}",
                gi(&["events", "generated"]),
                gi(&["events", "processed"]),
                gi(&["events", "emitted"])
            ),
        ],
        vec![
            "offered throughput".into(),
            format!("{} ev/s", fmt_count(gf(&["throughput", "offered"]))),
        ],
        vec![
            "processed throughput".into(),
            format!("{} ev/s", fmt_count(gf(&["throughput", "processed"]))),
        ],
        vec![
            "e2e latency".into(),
            format!(
                "p50 {} p99 {}",
                fmt_micros(gf(&["latency_us", "end_to_end", "p50"]) as u64),
                fmt_micros(gf(&["latency_us", "end_to_end", "p99"]) as u64)
            ),
        ],
        vec![
            "transport".into(),
            format!(
                "{} records, {} frames, {:.1} MiB",
                gi(&["transport", "records"]),
                gi(&["transport", "frames"]),
                gi(&["transport", "bytes"]) as f64 / (1024.0 * 1024.0)
            ),
        ],
    ];
    println!("{}", ascii_table(&["metric", "value"], &rows));
}

/// Escalate each configured experiment to its maximum sustainable
/// throughput and write `report.json` + `report.md` per experiment.
fn cmd_max_capacity(flags: &Flags) -> Result<(), String> {
    let exps = load_experiments(flags)?;
    let out_dir = PathBuf::from(flags.get("out").unwrap_or("runs"));
    let rtf = RuntimeFactory::default_dir();
    for exp in &exps {
        let dir = out_dir.join(format!("{}-maxcap", exp.name));
        std::fs::create_dir_all(&dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        println!("# max-capacity sweep: {} ({:?} mode)", exp.name, exp.config.bench.mode);
        let rtf = rtf.clone();
        let mut driver =
            MaxCapacityDriver::new(exp.config.clone(), move |cfg: &BenchConfig| {
                run_once(cfg, &rtf)
            });
        let report = driver.run()?;
        std::fs::write(dir.join("report.json"), report.to_json().to_pretty())
            .map_err(|e| format!("write report.json: {e}"))?;
        let md = report.to_markdown();
        std::fs::write(dir.join("report.md"), &md)
            .map_err(|e| format!("write report.md: {e}"))?;
        println!("{md}");
        println!("reports written to {}", dir.display());
    }
    Ok(())
}

fn print_summary(s: &crate::coordinator::RunSummary) {
    use crate::metrics::MeasurementPoint as P;
    let lat = |p: P| {
        s.latency_at(p)
            .filter(|h| h.count > 0)
            .map(|h| format!("p50 {} p99 {}", fmt_micros(h.p50), fmt_micros(h.p99)))
            .unwrap_or_else(|| "-".into())
    };
    let mut rows = vec![
        vec!["experiment".into(), s.name.clone()],
        vec![
            "pipeline / framework".into(),
            format!("{} / {} (P={})", s.pipeline, s.framework, s.parallelism),
        ],
        vec![
            "events gen/proc/emit".into(),
            format!("{} / {} / {}", s.generated, s.processed, s.emitted),
        ],
        vec![
            "offered throughput".into(),
            format!(
                "{} ev/s ({})",
                fmt_count(s.offered_rate),
                fmt_rate_bytes(s.offered_bytes_rate)
            ),
        ],
        vec![
            "processed throughput".into(),
            format!("{} ev/s", fmt_count(s.processed_rate)),
        ],
        vec!["e2e latency".into(), lat(P::EndToEnd)],
        vec!["processing latency".into(), lat(P::ProcOut)],
        vec![
            "GC young (count/time)".into(),
            format!("{} / {:.1}ms", s.gc_young_count, s.gc_young_time_micros as f64 / 1e3),
        ],
        vec!["energy".into(), format!("{:.1} J", s.energy_joules)],
    ];
    if let Some(r) = &s.recovery {
        rows.push(vec![
            "recovery".into(),
            format!(
                "{} after kill ({} replayed, {})",
                fmt_micros(r.recovery_time_micros),
                r.replayed_records,
                if r.cold_start {
                    "cold start".to_string()
                } else {
                    format!("restored epoch {}", r.restored_epoch)
                }
            ),
        ]);
        rows.push(vec![
            "checkpoints".into(),
            format!(
                "{} committed, {} B, write {} ({} corrupt skipped)",
                r.checkpoints, r.checkpoint_bytes,
                fmt_micros(r.checkpoint_write_micros), r.corrupt_skipped
            ),
        ]);
    }
    if let Some(res) = &s.resilience {
        rows.push(vec![
            "faults inj/det/healed".into(),
            format!("{} / {} / {}", res.injected, res.detected, res.healed),
        ]);
        rows.push(vec![
            "supervisor".into(),
            format!(
                "{} restart(s) ({} cold), detect {}, mttr {}, down {}",
                res.restart_count,
                res.cold_starts,
                fmt_micros(res.detect_micros),
                fmt_micros(res.mttr_micros),
                fmt_micros(res.downtime_micros)
            ),
        ]);
        if res.poison_records > 0 {
            rows.push(vec![
                "quarantine".into(),
                format!(
                    "{} poison record(s), {} dead-letter sample(s)",
                    res.poison_records,
                    res.dead_letters.len()
                ),
            ]);
        }
    }
    for f in &s.faults {
        rows.push(vec![
            format!("fault {}", f.spec.kind.name()),
            format!(
                "{} @{}: detect {}, mttr {}{}",
                f.spec.kind.target(),
                fmt_micros(f.spec.at_micros),
                fmt_micros(f.detect_micros()),
                fmt_micros(f.mttr_micros()),
                if f.injected_at.is_none() {
                    " (never injected)"
                } else if f.healed_at.is_none() {
                    " (UNHEALED)"
                } else {
                    ""
                }
            ),
        ]);
    }
    println!("{}", ascii_table(&["metric", "value"], &rows));
    if !s.operators.is_empty() {
        println!("per-operator stats (merged across tasks):");
        println!("{}", operator_stats_table(&s.operators));
    }
}

fn cmd_sbatch(flags: &Flags) -> Result<(), String> {
    let exps = load_experiments(flags)?;
    let config_path = flags.get("config").expect("checked in load_experiments");
    for exp in &exps {
        println!("# ---- {} ----", exp.name);
        println!("{}", crate::slurm::sbatch_script(&exp.config, config_path));
    }
    if flags.has("simulate") {
        let mut sched = Scheduler::new(ClusterSpec::default());
        let wm = WorkflowManager::new("runs");
        let ids = wm.submit_batch(&exps, &mut sched, flags.has("chain"), |e| {
            e.config.bench.duration_micros + e.config.bench.warmup_micros
        });
        let makespan = sched.run_to_completion();
        let rows: Vec<Vec<String>> = ids
            .iter()
            .map(|&id| {
                let j = sched.job(id).expect("job exists");
                vec![
                    j.request.name.clone(),
                    format!("{:?}", j.state),
                    fmt_micros(j.wait_micros().unwrap_or(0)),
                    fmt_micros(j.end_micros.unwrap_or(0).saturating_sub(j.start_micros.unwrap_or(0))),
                    format!("{}", j.allocated_nodes.len()),
                ]
            })
            .collect();
        println!(
            "{}",
            ascii_table(&["job", "state", "wait", "runtime", "nodes"], &rows)
        );
        println!("simulated makespan: {}", fmt_micros(makespan));
        let st = sched.stats();
        println!(
            "scheduler: {} submitted, {} completed, {} backfilled, utilization {:.1}%",
            st.submitted,
            st.completed,
            st.backfilled,
            st.utilization * 100.0
        );
    }
    Ok(())
}

fn cmd_report(flags: &Flags) -> Result<(), String> {
    let run_dir = PathBuf::from(flags.get("run").ok_or("--run <dir> is required")?);
    let results_path = run_dir.join("results.json");
    let text = std::fs::read_to_string(&results_path)
        .map_err(|e| format!("cannot read {}: {e}", results_path.display()))?;
    let results = json::parse(&text).map_err(|e| e.to_string())?;
    let violations = validate_results(&results);
    let mut rows = Vec::new();
    flatten_json("", &results, &mut rows);
    println!("{}", ascii_table(&["field", "value"], &rows));
    if violations.is_empty() {
        println!("validation: OK");
        Ok(())
    } else {
        for v in &violations {
            println!("validation FAILED [{}]: {}", v.check, v.detail);
        }
        Err("validation failed".into())
    }
}

fn flatten_json(prefix: &str, j: &Json, rows: &mut Vec<Vec<String>>) {
    match j {
        Json::Obj(m) => {
            for (k, v) in m {
                let key = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                flatten_json(&key, v, rows);
            }
        }
        other => rows.push(vec![prefix.to_string(), other.to_string()]),
    }
}

fn cmd_baselines(flags: &Flags) -> Result<(), String> {
    let events: u64 = flags
        .get("events")
        .map(|v| crate::util::units::parse_count(v))
        .transpose()?
        .unwrap_or(50_000);
    let clk = crate::util::clock::wall();
    let mut rows = Vec::new();
    for spec in crate::baselines::all_baselines() {
        let r = crate::baselines::run_baseline(&spec, events, 3_000_000, &clk);
        rows.push(vec![
            spec.name.to_string(),
            fmt_count(spec.doc_rate),
            fmt_count(r.rate),
        ]);
    }
    let sp = crate::baselines::run_sprobench_generator(events.max(200_000), 27, &clk);
    rows.push(vec![
        "SProBench (1 inst)".into(),
        fmt_count(500_000.0),
        fmt_count(sp.rate),
    ]);
    println!(
        "{}",
        ascii_table(&["suite", "documented max", "measured here"], &rows)
    );
    Ok(())
}

/// Sort one `analyze` word into pass selection vs option flags.  Needed
/// because `Flags::parse` turns `--bless panics` into a pair, so flag
/// names can surface as either pair keys or bare words.
fn classify_analyze_arg(
    word: &str,
    passes: &mut Vec<String>,
    bless: &mut bool,
    verbose: &mut bool,
) -> Result<(), String> {
    match word {
        "all" => Ok(()), // the default: empty pass selection = all
        "bless" => {
            *bless = true;
            Ok(())
        }
        "verbose" => {
            *verbose = true;
            Ok(())
        }
        p if crate::analysis::PASS_NAMES.contains(&p) => {
            passes.push(p.to_string());
            Ok(())
        }
        other => Err(format!(
            "analyze: unknown pass or flag '{other}' (passes: {})",
            crate::analysis::PASS_NAMES.join(", ")
        )),
    }
}

fn cmd_analyze(flags: &Flags) -> Result<(), String> {
    let mut passes: Vec<String> = Vec::new();
    let mut bless = false;
    let mut verbose = false;
    let mut root: Option<String> = None;
    let mut json_out: Option<String> = None;
    let mut sarif_out: Option<String> = None;
    let mut changed_since: Option<String> = None;

    for word in &flags.bare {
        classify_analyze_arg(word, &mut passes, &mut bless, &mut verbose)?;
    }
    for (key, value) in &flags.pairs {
        match key.as_str() {
            "root" => root = Some(value.clone()),
            "json" => json_out = Some(value.clone()),
            "sarif" => sarif_out = Some(value.clone()),
            "changed-since" => changed_since = Some(value.clone()),
            "all" | "bless" | "verbose" => {
                classify_analyze_arg(key, &mut passes, &mut bless, &mut verbose)?;
                classify_analyze_arg(value, &mut passes, &mut bless, &mut verbose)?;
            }
            other => return Err(format!("analyze: unknown flag --{other}")),
        }
    }

    let opts = crate::analysis::AnalyzeOptions {
        root: PathBuf::from(root.as_deref().unwrap_or(".")),
        passes,
        bless,
        changed_since,
    };
    let report = crate::analysis::run(&opts)?;
    print!("{}", report.render(verbose));

    let out = PathBuf::from(json_out.as_deref().unwrap_or("analysis_report.json"));
    std::fs::write(&out, report.to_json().to_pretty())
        .map_err(|e| format!("write {}: {e}", out.display()))?;
    if let Some(sarif) = &sarif_out {
        std::fs::write(sarif, report.to_sarif().to_pretty())
            .map_err(|e| format!("write {sarif}: {e}"))?;
    }

    let errors = report.error_count();
    if errors > 0 {
        return Err(format!(
            "analyze: {errors} error finding(s) — see {} for the full report",
            out.display()
        ));
    }
    Ok(())
}

fn cmd_list(flags: &Flags) -> Result<(), String> {
    let exps = load_experiments(flags)?;
    let rows: Vec<Vec<String>> = exps
        .iter()
        .map(|e| {
            vec![
                e.name.clone(),
                format!("{:?}", e.config.bench.mode),
                e.config.engine.pipeline_label(),
                e.config.engine.parallelism.to_string(),
                fmt_count(e.config.workload.rate as f64),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(&["experiment", "mode", "pipeline", "par", "rate"], &rows)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse_pairs_and_bare() {
        let args: Vec<String> = ["--config", "x.yaml", "--simulate", "--out", "dir"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = Flags::parse(&args);
        assert_eq!(f.get("config"), Some("x.yaml"));
        assert_eq!(f.get("out"), Some("dir"));
        assert!(f.has("simulate"));
        assert!(!f.has("chain"));
    }

    #[test]
    fn unknown_command_errors() {
        let err = dispatch(&["frobnicate".to_string()]).unwrap_err();
        assert!(err.contains("unknown command"));
    }

    #[test]
    fn version_and_help_work() {
        dispatch(&["version".to_string()]).unwrap();
        dispatch(&["help".to_string()]).unwrap();
        dispatch(&[]).unwrap();
    }

    #[test]
    fn run_requires_config() {
        let err = dispatch(&["run".to_string()]).unwrap_err();
        assert!(err.contains("--config"));
    }

    #[test]
    fn max_capacity_writes_reports_from_a_sim_config() {
        let dir = std::env::temp_dir().join(format!("sprobench-maxcap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("maxcap.yaml");
        std::fs::write(
            &cfg,
            "benchmark:
  name: mc
  mode: sim
  duration: 10s
workload:
  rate: 1M
engine:
  pipeline: passthrough
experiment:
  step_factor: 2.0
  max_iterations: 6
  refine_steps: 3
",
        )
        .unwrap();
        let out = dir.join("out");
        dispatch(&[
            "max-capacity".into(),
            "--config".into(),
            cfg.display().to_string(),
            "--out".into(),
            out.display().to_string(),
        ])
        .unwrap();
        let report_dir = out.join("mc-maxcap");
        let json_text = std::fs::read_to_string(report_dir.join("report.json")).unwrap();
        let report = crate::experiment::ExperimentReport::from_json(
            &json::parse(&json_text).unwrap(),
        )
        .unwrap();
        assert!(report.iterations.len() >= 2, "multi-iteration escalation");
        assert!(report.mst_target_rate >= 1_000_000, "sim capacity is well above 1M");
        let md = std::fs::read_to_string(report_dir.join("report.md")).unwrap();
        assert!(md.contains("Maximum sustainable throughput"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Find `results.json` under the single run directory for `name`.
    fn results_json_under(out: &Path, name: &str) -> Json {
        let dir = std::fs::read_dir(out)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .find(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with(name))
            })
            .unwrap_or_else(|| panic!("no run dir for {name} under {}", out.display()));
        let text = std::fs::read_to_string(dir.join("results.json")).unwrap();
        json::parse(&text).unwrap()
    }

    #[test]
    fn chained_spec_runs_end_to_end_through_the_cli() {
        // A filter→keyby→window→topk→emit chain, declared in the master
        // YAML, executed wall-mode through `sprobench run`.
        let dir = std::env::temp_dir().join(format!("sprobench-chain-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("chain.yaml");
        std::fs::write(
            &cfg,
            "benchmark:
  name: chaintest
  duration: 800ms
  warmup: 0s
workload:
  rate: 40K
  sensors: 256
engine:
  parallelism: 2
  use_hlo: false
  pipeline:
    ops:
      - filter:
          cmp: gt
          value: 15.0
      - keyby:
          modulo: 32
      - window:
          agg: mean
          window: 200ms
          slide: 100ms
      - topk:
          k: 5
      - emit: aggregates
",
        )
        .unwrap();
        let out = dir.join("out");
        dispatch(&[
            "run".into(),
            "--config".into(),
            cfg.display().to_string(),
            "--out".into(),
            out.display().to_string(),
        ])
        .unwrap();
        let results = results_json_under(&out, "chaintest");
        assert_eq!(
            results.get("pipeline").and_then(|v| v.as_str()),
            Some("chain[filter→keyby→window→topk→emit_aggregates]")
        );
        // The keyed chain stages at the keyby and topk boundaries, so the
        // report carries one `exchange` entry per boundary.
        let ops = results.get("operators").and_then(|v| v.as_arr()).unwrap();
        let names: Vec<&str> = ops
            .iter()
            .filter_map(|o| o.get("op").and_then(|v| v.as_str()))
            .collect();
        assert_eq!(
            names,
            vec!["filter", "keyby", "exchange", "window", "exchange", "topk", "emit_aggregates"]
        );
        let processed = results.path(&["events", "processed"]).unwrap().as_i64().unwrap();
        assert!(processed > 0);
        let emitted = results.path(&["events", "emitted"]).unwrap().as_i64().unwrap();
        assert!(emitted > 0, "chained topology must emit top-k aggregates");
        // Exchange accounting: the filter passes most rows, and every
        // surviving row crosses the first boundary.
        let xchg: i64 = ops
            .iter()
            .filter(|o| o.get("op").and_then(|v| v.as_str()) == Some("exchange"))
            .filter_map(|o| o.get("exchange_records").and_then(|v| v.as_i64()))
            .sum();
        assert!(xchg > 0, "rows must cross the exchange: {results:?}");
        // topk bounds emissions: ≤ k per window emission.
        let window_emits: i64 = ops
            .iter()
            .filter(|o| o.get("op").and_then(|v| v.as_str()) == Some("window"))
            .filter_map(|o| o.get("window_emits").and_then(|v| v.as_i64()))
            .sum();
        assert!(emitted <= window_emits * 5, "emitted {emitted} > {window_emits} windows × k");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pipeline_spec_flag_overrides_the_configured_pipeline() {
        // The projection chain (filter→map→emit) from a standalone spec
        // file, over a sim-mode base config that says `pipeline: mem`.
        let dir = std::env::temp_dir().join(format!("sprobench-specflag-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("base.yaml");
        std::fs::write(
            &cfg,
            "benchmark:\n  name: specflag\n  mode: sim\n  duration: 10s\nworkload:\n  rate: 1M\nengine:\n  pipeline: mem\n",
        )
        .unwrap();
        let spec = dir.join("projection.yaml");
        std::fs::write(
            &spec,
            "ops:\n  - filter:\n      cmp: gt\n      value: 20.0\n  - map:\n      scale: 1.8\n      offset: 32.0\n  - emit: events\n",
        )
        .unwrap();
        let out = dir.join("out");
        dispatch(&[
            "run".into(),
            "--config".into(),
            cfg.display().to_string(),
            "--pipeline-spec".into(),
            spec.display().to_string(),
            "--out".into(),
            out.display().to_string(),
        ])
        .unwrap();
        let results = results_json_under(&out, "specflag");
        assert_eq!(
            results.get("pipeline").and_then(|v| v.as_str()),
            Some("chain[filter→map→emit_events]")
        );
        // A malformed spec file must fail with the grammar in the error.
        std::fs::write(&spec, "ops:\n  - window:\n      agg: median\n").unwrap();
        let err = dispatch(&[
            "run".into(),
            "--config".into(),
            cfg.display().to_string(),
            "--pipeline-spec".into(),
            spec.display().to_string(),
        ])
        .unwrap_err();
        assert!(err.contains("unknown agg"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn custom_ops_are_rejected_before_launch_with_builtin_list() {
        // The CLI can never supply an OperatorRegistry; a custom (or
        // typo'd) op name must fail at load, not inside an engine task.
        let dir = std::env::temp_dir().join(format!("sprobench-customop-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("typo.yaml");
        std::fs::write(
            &cfg,
            "benchmark:\n  name: typo\n  mode: sim\nengine:\n  pipeline:\n    ops:\n      - fitler:\n          value: 20.0\n      - emit: events\n",
        )
        .unwrap();
        let err = dispatch(&["run".into(), "--config".into(), cfg.display().to_string()])
            .unwrap_err();
        assert!(err.contains("fitler"), "{err}");
        assert!(err.contains("built-ins"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn list_and_sbatch_from_a_real_config() {
        let dir = std::env::temp_dir().join(format!("sprobench-cli-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("bench.yaml");
        std::fs::write(
            &cfg,
            "benchmark:\n  name: clitest\nworkload:\n  rate: 100K\nexperiments:\n  - name: a\n    engine.parallelism: 2\n  - name: b\n    engine.parallelism: 4\n",
        )
        .unwrap();
        dispatch(&["list".into(), "--config".into(), cfg.display().to_string()]).unwrap();
        dispatch(&[
            "sbatch".into(),
            "--config".into(),
            cfg.display().to_string(),
            "--simulate".into(),
        ])
        .unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
