//! Command-line interface (hand-rolled; clap is not vendored offline).
//!
//! The paper's CLI orchestrates "all components, setting up frameworks,
//! compiling the resources and performing the benchmarks", on local
//! machines and SLURM clusters, interactive and batch (Sec. 3).
//!
//! ```text
//! sprobench run          --config <file> [--experiment <name>] [--out <dir>]
//! sprobench max-capacity --config <file> [--experiment <name>] [--out <dir>]
//! sprobench sbatch       --config <file> [--simulate] [--chain]
//! sprobench report       --run <dir>
//! sprobench baselines    [--events <n>]
//! sprobench list         --config <file>
//! sprobench version | help
//! ```

use std::path::{Path, PathBuf};

use crate::config::{self, BenchConfig, ExecMode, Experiment};
use crate::coordinator::{run_wall, simrun};
use crate::experiment::MaxCapacityDriver;
use crate::postprocess::{ascii_table, validate_results};
use crate::runtime::RuntimeFactory;
use crate::slurm::{ClusterSpec, Scheduler};
use crate::util::json::{self, Json};
use crate::util::units::{fmt_count, fmt_micros, fmt_rate_bytes};
use crate::workflow::WorkflowManager;

/// Entry point; returns the process exit code.
pub fn main() -> i32 {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

/// Parsed flag set: `--key value` pairs + bare flags.
struct Flags {
    pairs: Vec<(String, String)>,
    bare: Vec<String>,
}

impl Flags {
    fn parse(args: &[String]) -> Flags {
        let mut pairs = Vec::new();
        let mut bare = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    pairs.push((key.to_string(), args[i + 1].clone()));
                    i += 2;
                } else {
                    bare.push(key.to_string());
                    i += 1;
                }
            } else {
                bare.push(a.clone());
                i += 1;
            }
        }
        Flags { pairs, bare }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.pairs
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn has(&self, key: &str) -> bool {
        self.bare.iter().any(|b| b == key)
    }
}

pub fn dispatch(args: &[String]) -> Result<(), String> {
    let Some(cmd) = args.first().map(|s| s.as_str()) else {
        println!("{}", usage());
        return Ok(());
    };
    let flags = Flags::parse(&args[1..]);
    match cmd {
        "run" => cmd_run(&flags),
        "max-capacity" => cmd_max_capacity(&flags),
        "sbatch" => cmd_sbatch(&flags),
        "report" => cmd_report(&flags),
        "baselines" => cmd_baselines(&flags),
        "list" => cmd_list(&flags),
        "version" => {
            println!("sprobench {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage())),
    }
}

fn usage() -> &'static str {
    "SProBench — stream processing benchmark for HPC infrastructure

USAGE:
  sprobench run          --config <file> [--experiment <name>] [--out <dir>]
  sprobench max-capacity --config <file> [--experiment <name>] [--out <dir>]
  sprobench sbatch       --config <file> [--simulate] [--chain]
  sprobench report       --run <dir>
  sprobench baselines    [--events <n>]
  sprobench list         --config <file>
  sprobench version | help

The config file is the single master control point (YAML); its
`experiments:` list expands into one run per entry.  `max-capacity`
escalates the offered load until the sustainability predicate fails
(see the `experiment:` config section) and writes report.json +
report.md with the maximum sustainable throughput."
}

fn load_experiments(flags: &Flags) -> Result<Vec<Experiment>, String> {
    let path = flags.get("config").ok_or("--config <file> is required")?;
    let mut exps = config::load_file(Path::new(path))?;
    if let Some(name) = flags.get("experiment") {
        exps.retain(|e| e.name == name);
        if exps.is_empty() {
            return Err(format!("no experiment named '{name}' in {path}"));
        }
    }
    Ok(exps)
}

/// Execute one resolved config through the mode-appropriate entry point
/// (shared by `run` and `max-capacity`).
fn run_once(
    cfg: &BenchConfig,
    rtf: &RuntimeFactory,
) -> Result<
    (
        crate::coordinator::RunSummary,
        std::sync::Arc<crate::metrics::MetricStore>,
    ),
    String,
> {
    match cfg.bench.mode {
        ExecMode::Wall => run_wall(cfg, cfg.engine.use_hlo.then(|| rtf.clone())),
        ExecMode::Sim => Ok(simrun::run_sim(cfg, &simrun::SimModel::default())),
    }
}

fn cmd_run(flags: &Flags) -> Result<(), String> {
    let exps = load_experiments(flags)?;
    let out_dir = PathBuf::from(flags.get("out").unwrap_or("runs"));
    let wm = WorkflowManager::new(&out_dir);
    let rtf = RuntimeFactory::default_dir();
    let outcomes = wm.run_all(&exps, |exp, dir| {
        dir.step(&format!(
            "mode={:?} pipeline={} parallelism={}",
            exp.config.bench.mode,
            exp.config.engine.pipeline.name(),
            exp.config.engine.parallelism
        ));
        let (summary, store) = run_once(&exp.config, &rtf)?;
        dir.step("exporting metrics");
        std::fs::write(dir.metrics_dir().join("series.json"), store.to_json().to_pretty())
            .map_err(|e| format!("write metrics: {e}"))?;
        let results = summary.to_json();
        let violations = validate_results(&results);
        if !violations.is_empty() {
            dir.step(&format!("VALIDATION FAILED: {violations:?}"));
            return Err(format!("{}: validation failed: {violations:?}", exp.name));
        }
        dir.step("validation passed");
        print_summary(&summary);
        Ok(results)
    })?;
    println!("\n{} run(s) complete; results under {}", outcomes.len(), out_dir.display());
    Ok(())
}

/// Escalate each configured experiment to its maximum sustainable
/// throughput and write `report.json` + `report.md` per experiment.
fn cmd_max_capacity(flags: &Flags) -> Result<(), String> {
    let exps = load_experiments(flags)?;
    let out_dir = PathBuf::from(flags.get("out").unwrap_or("runs"));
    let rtf = RuntimeFactory::default_dir();
    for exp in &exps {
        let dir = out_dir.join(format!("{}-maxcap", exp.name));
        std::fs::create_dir_all(&dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        println!("# max-capacity sweep: {} ({:?} mode)", exp.name, exp.config.bench.mode);
        let rtf = rtf.clone();
        let mut driver =
            MaxCapacityDriver::new(exp.config.clone(), move |cfg: &BenchConfig| {
                run_once(cfg, &rtf)
            });
        let report = driver.run()?;
        std::fs::write(dir.join("report.json"), report.to_json().to_pretty())
            .map_err(|e| format!("write report.json: {e}"))?;
        let md = report.to_markdown();
        std::fs::write(dir.join("report.md"), &md)
            .map_err(|e| format!("write report.md: {e}"))?;
        println!("{md}");
        println!("reports written to {}", dir.display());
    }
    Ok(())
}

fn print_summary(s: &crate::coordinator::RunSummary) {
    use crate::metrics::MeasurementPoint as P;
    let lat = |p: P| {
        s.latency_at(p)
            .filter(|h| h.count > 0)
            .map(|h| format!("p50 {} p99 {}", fmt_micros(h.p50), fmt_micros(h.p99)))
            .unwrap_or_else(|| "-".into())
    };
    let rows = vec![
        vec!["experiment".into(), s.name.clone()],
        vec![
            "pipeline / framework".into(),
            format!("{} / {} (P={})", s.pipeline, s.framework, s.parallelism),
        ],
        vec![
            "events gen/proc/emit".into(),
            format!("{} / {} / {}", s.generated, s.processed, s.emitted),
        ],
        vec![
            "offered throughput".into(),
            format!(
                "{} ev/s ({})",
                fmt_count(s.offered_rate),
                fmt_rate_bytes(s.offered_bytes_rate)
            ),
        ],
        vec![
            "processed throughput".into(),
            format!("{} ev/s", fmt_count(s.processed_rate)),
        ],
        vec!["e2e latency".into(), lat(P::EndToEnd)],
        vec!["processing latency".into(), lat(P::ProcOut)],
        vec![
            "GC young (count/time)".into(),
            format!("{} / {:.1}ms", s.gc_young_count, s.gc_young_time_micros as f64 / 1e3),
        ],
        vec!["energy".into(), format!("{:.1} J", s.energy_joules)],
    ];
    println!("{}", ascii_table(&["metric", "value"], &rows));
}

fn cmd_sbatch(flags: &Flags) -> Result<(), String> {
    let exps = load_experiments(flags)?;
    let config_path = flags.get("config").expect("checked in load_experiments");
    for exp in &exps {
        println!("# ---- {} ----", exp.name);
        println!("{}", crate::slurm::sbatch_script(&exp.config, config_path));
    }
    if flags.has("simulate") {
        let mut sched = Scheduler::new(ClusterSpec::default());
        let wm = WorkflowManager::new("runs");
        let ids = wm.submit_batch(&exps, &mut sched, flags.has("chain"), |e| {
            e.config.bench.duration_micros + e.config.bench.warmup_micros
        });
        let makespan = sched.run_to_completion();
        let rows: Vec<Vec<String>> = ids
            .iter()
            .map(|&id| {
                let j = sched.job(id).expect("job exists");
                vec![
                    j.request.name.clone(),
                    format!("{:?}", j.state),
                    fmt_micros(j.wait_micros().unwrap_or(0)),
                    fmt_micros(j.end_micros.unwrap_or(0).saturating_sub(j.start_micros.unwrap_or(0))),
                    format!("{}", j.allocated_nodes.len()),
                ]
            })
            .collect();
        println!(
            "{}",
            ascii_table(&["job", "state", "wait", "runtime", "nodes"], &rows)
        );
        println!("simulated makespan: {}", fmt_micros(makespan));
        let st = sched.stats();
        println!(
            "scheduler: {} submitted, {} completed, {} backfilled, utilization {:.1}%",
            st.submitted,
            st.completed,
            st.backfilled,
            st.utilization * 100.0
        );
    }
    Ok(())
}

fn cmd_report(flags: &Flags) -> Result<(), String> {
    let run_dir = PathBuf::from(flags.get("run").ok_or("--run <dir> is required")?);
    let results_path = run_dir.join("results.json");
    let text = std::fs::read_to_string(&results_path)
        .map_err(|e| format!("cannot read {}: {e}", results_path.display()))?;
    let results = json::parse(&text).map_err(|e| e.to_string())?;
    let violations = validate_results(&results);
    let mut rows = Vec::new();
    flatten_json("", &results, &mut rows);
    println!("{}", ascii_table(&["field", "value"], &rows));
    if violations.is_empty() {
        println!("validation: OK");
        Ok(())
    } else {
        for v in &violations {
            println!("validation FAILED [{}]: {}", v.check, v.detail);
        }
        Err("validation failed".into())
    }
}

fn flatten_json(prefix: &str, j: &Json, rows: &mut Vec<Vec<String>>) {
    match j {
        Json::Obj(m) => {
            for (k, v) in m {
                let key = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                flatten_json(&key, v, rows);
            }
        }
        other => rows.push(vec![prefix.to_string(), other.to_string()]),
    }
}

fn cmd_baselines(flags: &Flags) -> Result<(), String> {
    let events: u64 = flags
        .get("events")
        .map(|v| crate::util::units::parse_count(v))
        .transpose()?
        .unwrap_or(50_000);
    let clk = crate::util::clock::wall();
    let mut rows = Vec::new();
    for spec in crate::baselines::all_baselines() {
        let r = crate::baselines::run_baseline(&spec, events, 3_000_000, &clk);
        rows.push(vec![
            spec.name.to_string(),
            fmt_count(spec.doc_rate),
            fmt_count(r.rate),
        ]);
    }
    let sp = crate::baselines::run_sprobench_generator(events.max(200_000), 27, &clk);
    rows.push(vec![
        "SProBench (1 inst)".into(),
        fmt_count(500_000.0),
        fmt_count(sp.rate),
    ]);
    println!(
        "{}",
        ascii_table(&["suite", "documented max", "measured here"], &rows)
    );
    Ok(())
}

fn cmd_list(flags: &Flags) -> Result<(), String> {
    let exps = load_experiments(flags)?;
    let rows: Vec<Vec<String>> = exps
        .iter()
        .map(|e| {
            vec![
                e.name.clone(),
                format!("{:?}", e.config.bench.mode),
                e.config.engine.pipeline.name().to_string(),
                e.config.engine.parallelism.to_string(),
                fmt_count(e.config.workload.rate as f64),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(&["experiment", "mode", "pipeline", "par", "rate"], &rows)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_parse_pairs_and_bare() {
        let args: Vec<String> = ["--config", "x.yaml", "--simulate", "--out", "dir"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let f = Flags::parse(&args);
        assert_eq!(f.get("config"), Some("x.yaml"));
        assert_eq!(f.get("out"), Some("dir"));
        assert!(f.has("simulate"));
        assert!(!f.has("chain"));
    }

    #[test]
    fn unknown_command_errors() {
        let err = dispatch(&["frobnicate".to_string()]).unwrap_err();
        assert!(err.contains("unknown command"));
    }

    #[test]
    fn version_and_help_work() {
        dispatch(&["version".to_string()]).unwrap();
        dispatch(&["help".to_string()]).unwrap();
        dispatch(&[]).unwrap();
    }

    #[test]
    fn run_requires_config() {
        let err = dispatch(&["run".to_string()]).unwrap_err();
        assert!(err.contains("--config"));
    }

    #[test]
    fn max_capacity_writes_reports_from_a_sim_config() {
        let dir = std::env::temp_dir().join(format!("sprobench-maxcap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("maxcap.yaml");
        std::fs::write(
            &cfg,
            "benchmark:
  name: mc
  mode: sim
  duration: 10s
workload:
  rate: 1M
engine:
  pipeline: passthrough
experiment:
  step_factor: 2.0
  max_iterations: 6
  refine_steps: 3
",
        )
        .unwrap();
        let out = dir.join("out");
        dispatch(&[
            "max-capacity".into(),
            "--config".into(),
            cfg.display().to_string(),
            "--out".into(),
            out.display().to_string(),
        ])
        .unwrap();
        let report_dir = out.join("mc-maxcap");
        let json_text = std::fs::read_to_string(report_dir.join("report.json")).unwrap();
        let report = crate::experiment::ExperimentReport::from_json(
            &json::parse(&json_text).unwrap(),
        )
        .unwrap();
        assert!(report.iterations.len() >= 2, "multi-iteration escalation");
        assert!(report.mst_target_rate >= 1_000_000, "sim capacity is well above 1M");
        let md = std::fs::read_to_string(report_dir.join("report.md")).unwrap();
        assert!(md.contains("Maximum sustainable throughput"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn list_and_sbatch_from_a_real_config() {
        let dir = std::env::temp_dir().join(format!("sprobench-cli-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("bench.yaml");
        std::fs::write(
            &cfg,
            "benchmark:\n  name: clitest\nworkload:\n  rate: 100K\nexperiments:\n  - name: a\n    engine.parallelism: 2\n  - name: b\n    engine.parallelism: 4\n",
        )
        .unwrap();
        dispatch(&["list".into(), "--config".into(), cfg.display().to_string()]).unwrap();
        dispatch(&[
            "sbatch".into(),
            "--config".into(),
            cfg.display().to_string(),
            "--simulate".into(),
        ])
        .unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
