//! Analytic cluster-scale execution (`mode: sim`).
//!
//! The paper's headline numbers come from a 630-node SLURM cluster; this
//! machine is one box.  `run_sim` evaluates the same experiment on a
//! *model* of the pipeline in virtual time: component capacities bound
//! throughput, a queueing term shapes latency, and the JVM/energy models
//! run forward analytically.  The model constants are calibrated against
//! wall-mode measurements on this machine (see EXPERIMENTS.md §Calibration)
//! so the *shape* of every curve — linearity in Fig. 6, the plateau in
//! Fig. 7, the GC growth in Fig. 8 — carries over; absolute cluster-scale
//! numbers are the model's.

use std::sync::Arc;

use super::RunSummary;
use crate::config::{BenchConfig, ExchangeMode, FaultKind, OpSpec, PipelineKind, PipelineSpec};
use crate::engine::supervisor::backoff_micros;
use crate::engine::{FaultOutcome, ResilienceStats};
use crate::metrics::{MeasurementPoint, MetricStore};
use crate::util::histogram::{Histogram, HistogramSummary};
use crate::util::rng::Pcg32;

/// Calibratable capacity/latency constants.
#[derive(Clone, Debug)]
pub struct SimModel {
    /// Broker append+fetch capacity per partition, events/second.
    pub broker_per_partition_rate: f64,
    /// Engine per-task processing rate by pipeline, events/second.
    pub task_rate_passthrough: f64,
    pub task_rate_cpu: f64,
    pub task_rate_mem: f64,
    pub task_rate_fused: f64,
    /// Fixed path latency floor (serialize + broker hop + dispatch), µs.
    pub base_latency_micros: f64,
    /// Per-task dispatch overhead per batch, µs (drives the Fig. 7
    /// latency growth with parallelism).
    pub per_task_dispatch_micros: f64,
    /// Per-event cost of crossing one keyed-exchange boundary, µs
    /// (route hash + channel handshake + drain), charged once per
    /// boundary when `engine.exchange: hash` stages the chain.
    pub exchange_per_event_micros: f64,
    /// Per-task pause to snapshot operator state and submit one aligned
    /// checkpoint epoch, µs.  With `checkpoint.interval` set, capacity
    /// derates by the pause's duty cycle (`1 - pause/interval`).
    pub checkpoint_pause_micros: f64,
    /// Job teardown + respawn + checkpoint read on a kill-and-restore,
    /// µs (the replay time is added on top from the modeled backlog).
    pub restart_micros: f64,
    /// JVM allocation per processed event, bytes.
    pub alloc_per_event: f64,
    /// Young-generation size per task, bytes.
    pub young_bytes: f64,
    /// Young GC pause, µs.
    pub young_pause_micros: f64,
    /// Node power model.
    pub idle_watts: f64,
    pub peak_watts: f64,
}

impl Default for SimModel {
    /// Constants recalibrated for the batch-first data plane (`RecordBatch`
    /// end-to-end): the broker pays one lock/condvar handshake per batch
    /// instead of per record, and the engine parses payload views without
    /// `Record` clones.  These are *projected* ratios pending a wall-mode
    /// run on the target machine — re-calibrate from `BENCH_hotpath.json`
    /// (`data_plane.speedup`, written by `cargo bench --bench
    /// hotpath_micro`) whenever the hot path changes.
    fn default() -> Self {
        Self {
            broker_per_partition_rate: 12.0e6,
            task_rate_passthrough: 4.2e6,
            task_rate_cpu: 1.5e6,
            task_rate_mem: 1.05e6,
            task_rate_fused: 0.95e6,
            base_latency_micros: 900.0,
            per_task_dispatch_micros: 110.0,
            exchange_per_event_micros: 0.18,
            checkpoint_pause_micros: 450.0,
            restart_micros: 250_000.0,
            alloc_per_event: 220.0,
            young_bytes: 64.0 * (1 << 20) as f64,
            young_pause_micros: 2_300.0,
            idle_watts: 240.0,
            peak_watts: 700.0,
        }
    }
}

impl SimModel {
    fn task_rate(&self, p: PipelineKind) -> f64 {
        match p {
            PipelineKind::PassThrough => self.task_rate_passthrough,
            PipelineKind::CpuIntensive => self.task_rate_cpu,
            PipelineKind::MemIntensive => self.task_rate_mem,
            PipelineKind::Fused => self.task_rate_fused,
        }
    }

    /// Per-task rate for an operator-chain spec: service times add along
    /// the chain.  The per-op costs are projections calibrated so the
    /// canonical kind chains land on the measured kind rates above
    /// (forward ≈ passthrough; cpu_transform + emit ≈ cpu; window + emit ≈
    /// mem); re-calibrate from `BENCH_hotpath.json` (`e2e data plane
    /// chained`) when the operator layer changes.
    fn task_rate_spec(&self, spec: &PipelineSpec, cfg: &BenchConfig) -> f64 {
        let op_cost: f64 = spec
            .ops
            .iter()
            .map(|op| match op {
                OpSpec::Forward => 1e6 / self.task_rate_passthrough,
                OpSpec::Filter { .. } => 0.08,
                OpSpec::Map { .. } => 0.06,
                OpSpec::KeyBy { .. } => 0.06,
                OpSpec::CpuTransform => 1e6 / self.task_rate_cpu - 0.25,
                // Event-time windows pay a small extra service time per
                // event: watermark bookkeeping + late routing, and the
                // native (non-HLO) accumulation path.
                OpSpec::Window { time, .. } => {
                    1e6 / self.task_rate_mem - 0.25
                        + match time {
                            crate::engine::WindowTime::Processing => 0.0,
                            crate::engine::WindowTime::Event => 0.06,
                        }
                }
                OpSpec::TopK { .. } => 0.12,
                OpSpec::EmitEvents | OpSpec::EmitAggregates => 0.25,
                OpSpec::Custom { .. } => 0.50,
            })
            .sum();
        // Exchange pricing: every keyed boundary the staged chain crosses
        // charges one route+transfer per event — the shuffle cost
        // ShuffleBench isolates, which `max-capacity` sweeps must see.
        let boundaries = if cfg.engine.exchange == ExchangeMode::Hash {
            spec.split_stages(cfg.engine.parallelism).len().saturating_sub(1)
        } else {
            0
        };
        let cost_micros = op_cost + boundaries as f64 * self.exchange_per_event_micros;
        1e6 / cost_micros.max(0.01)
    }

    fn task_rate_for(&self, cfg: &BenchConfig) -> f64 {
        match &cfg.engine.pipeline_spec {
            Some(spec) => self.task_rate_spec(spec, cfg),
            None => self.task_rate(cfg.engine.pipeline),
        }
    }
}

/// Evaluate one experiment analytically. Also emits a synthetic timeline
/// into a metric store (per-second samples with seeded jitter) so the
/// Fig. 8-style plots work identically in both modes.
pub fn run_sim(cfg: &BenchConfig, model: &SimModel) -> (RunSummary, Arc<MetricStore>) {
    let duration_s = (cfg.bench.duration_micros as f64 / 1e6).max(0.001);
    let instances = cfg.generator_instances() as f64;
    let offered = (cfg.workload.rate as f64)
        .min(instances * cfg.generators.instance_capacity as f64);

    let broker_cap = cfg.broker.partitions as f64 * model.broker_per_partition_rate;
    let par = cfg.engine.parallelism as f64;
    // Effective engine capacity scales sub-linearly at high parallelism:
    // coordination cost shaves (the Fig. 7 plateau).
    let scaling_eff = 1.0 / (1.0 + 0.04 * (par - 1.0));
    // Aligned checkpoints steal a snapshot pause from every task once per
    // epoch; capacity derates by the pause's duty cycle (bounded so a
    // pathological interval cannot zero the engine out).
    let ckpt_eff = if cfg.checkpoint.enabled() {
        1.0 - (model.checkpoint_pause_micros / cfg.checkpoint.interval_micros as f64).min(0.5)
    } else {
        1.0
    };
    let engine_cap = par * model.task_rate_for(cfg) * scaling_eff * ckpt_eff;

    let processed_rate = offered.min(broker_cap).min(engine_cap);
    let rho_engine = (processed_rate / engine_cap).min(0.999);
    let rho_broker = (processed_rate / broker_cap).min(0.999);

    // Latency: floor + batch fill + dispatch growing with parallelism +
    // M/M/1-style queueing amplification near saturation.
    let per_task_rate = (processed_rate / par).max(1.0);
    let batch_fill = cfg.engine.batch_size as f64 / per_task_rate * 1e6;
    let queueing = model.base_latency_micros * (1.0 / (1.0 - rho_engine) - 1.0)
        + model.base_latency_micros * 0.3 * (1.0 / (1.0 - rho_broker) - 1.0);
    let dispatch = model.per_task_dispatch_micros * par;
    let e2e_mean = model.base_latency_micros + batch_fill + dispatch + queueing.min(250_000.0);
    let broker_lat = model.base_latency_micros * 0.25 * (1.0 + rho_broker * 3.0);

    let generated = (offered * duration_s) as u64;
    let processed = (processed_rate * duration_s) as u64;

    // Fault schedule: model the supervisor's heal cycle analytically.
    // Each restart fault (kill/hang) prices detection — a kill is
    // observed as soon as the fleet dies, a hang only when the heartbeat
    // deadline passes — plus supervisor backoff, the restart pause, and
    // working off the checkpoint-replay backlog at full capacity.  The
    // kill lands mid-epoch, so on average half an interval of intake is
    // replayed.  Stalls and poison windows degrade in place: a stall
    // back-pressures (no distinct-record loss), poison quarantines
    // `fraction` of the offered stream while its window is open.  Faults
    // scheduled past the run's end are never injected, and restart
    // faults beyond `fault.max_restarts` stay unhealed — a wall run's
    // supervisor errors out at that point.
    let plan = cfg.fault.plan();
    let interval = cfg.checkpoint.interval_micros;
    let warm = cfg.checkpoint.enabled() && cfg.fault.restore;
    let replayed_per_restart = if cfg.checkpoint.enabled() {
        (processed_rate * interval as f64 / 2e6) as u64
    } else {
        // Eager per-batch commits: only the in-flight batches replay.
        (par * cfg.engine.batch_size as f64) as u64
    };
    let replay_micros = replayed_per_restart as f64 / engine_cap.max(1.0) * 1e6;
    let mut outcomes: Vec<FaultOutcome> = Vec::new();
    let mut restart_count: u64 = 0;
    let mut quarantined: u64 = 0;
    for f in &plan {
        let mut o = FaultOutcome::new(f.clone());
        if f.at_micros >= cfg.bench.duration_micros {
            outcomes.push(o);
            continue;
        }
        o.injected_at = Some(f.at_micros);
        match f.kind {
            FaultKind::KillTask { .. } | FaultKind::HangTask { .. } => {
                let detect = match f.kind {
                    FaultKind::HangTask { .. } => cfg.fault.heartbeat_timeout_micros,
                    _ => 1_000,
                };
                o.detected_at = Some(f.at_micros + detect);
                if restart_count < cfg.fault.max_restarts as u64 {
                    let pause = backoff_micros(cfg.fault.backoff_micros, restart_count as u32);
                    o.healed_at = Some(
                        f.at_micros
                            + detect
                            + pause
                            + (model.restart_micros + replay_micros) as u64,
                    );
                    restart_count += 1;
                }
            }
            FaultKind::StallPartition { .. } => {
                // Supervisor-tracked degradation: detection is the
                // injection itself; the release heals it.
                o.detected_at = Some(f.at_micros);
                o.healed_at =
                    Some((f.at_micros + f.duration_micros).min(cfg.bench.duration_micros));
            }
            FaultKind::PoisonRecords { fraction } => {
                let until = if f.duration_micros == 0 {
                    cfg.bench.duration_micros
                } else {
                    (f.at_micros + f.duration_micros).min(cfg.bench.duration_micros)
                };
                let window_s = until.saturating_sub(f.at_micros) as f64 / 1e6;
                quarantined += (offered * window_s * fraction) as u64;
                o.detected_at = Some(f.at_micros);
                o.healed_at = Some(until);
            }
            FaultKind::PeerDisconnect { .. } => {
                // Distributed-run detection only; the analytic model has
                // no TCP peers to lose.  Record the injection unhealed.
                o.detected_at = Some(f.at_micros);
            }
        }
        outcomes.push(o);
    }
    let total_replayed = restart_count * replayed_per_restart;
    let quarantined = quarantined.min(processed);
    // Quarantined records are counted, not processed: the parse path
    // rejects them before any operator sees them.
    let processed = processed - quarantined;

    // Keyed pipelines emit window aggregates, not 1:1 events.  For chain
    // specs the emission model follows the chain's shape: keys narrowed by
    // keyby, aggregates capped by topk.  (Filters are load-dependent and
    // left at the 1:1 bound.)
    let window_emitted = |slide: u64, keys: u64| -> u64 {
        (cfg.bench.duration_micros / slide.max(1)) * keys
    };
    let emitted = match &cfg.engine.pipeline_spec {
        Some(spec) if spec.has_window() => {
            // Position-sensitive: only keyby ops *upstream* of the first
            // window narrow the emitting key space, and that window's
            // slide sets the emission cadence.
            let mut keys = cfg.workload.sensors.min(1024) as u64;
            let mut slide = cfg.engine.slide_micros;
            let mut cap = u64::MAX;
            let mut saw_window = false;
            for op in &spec.ops {
                match op {
                    OpSpec::KeyBy { modulo, .. } if !saw_window => {
                        keys = keys.min(*modulo as u64)
                    }
                    OpSpec::Window { slide_micros, .. } if !saw_window => {
                        if *slide_micros > 0 {
                            slide = *slide_micros;
                        }
                        saw_window = true;
                    }
                    OpSpec::TopK { k, .. } => cap = *k as u64,
                    _ => {}
                }
            }
            window_emitted(slide, keys.min(cap))
        }
        Some(_) => processed,
        None => match cfg.engine.pipeline {
            PipelineKind::MemIntensive => {
                window_emitted(cfg.engine.slide_micros, cfg.workload.sensors.min(1024) as u64)
            }
            _ => processed,
        },
    };

    // Legacy `recovery` block: derived from the first injected restart
    // fault, mirroring wall-mode semantics (`recovery_time` is that
    // fault's injection→healed span).
    let recovery = outcomes
        .iter()
        .find(|o| o.spec.needs_restart() && o.injected_at.is_some())
        .map(|first| {
            let epochs = if interval > 0 {
                (first.spec.at_micros / interval).max(1)
            } else {
                0
            };
            // Snapshot payload ~ a few hundred bytes of offsets/counters
            // per task plus window pane state for keyed pipelines.
            let bytes_per = 220 * cfg.engine.parallelism as u64
                + 24 * cfg.workload.sensors.min(1024) as u64;
            super::RecoveryStats {
                recovery_time_micros: first.mttr_micros(),
                replayed_records: total_replayed,
                restored_epoch: if warm { epochs } else { 0 },
                cold_start: !warm,
                corrupt_skipped: 0,
                checkpoints: epochs,
                checkpoint_bytes: epochs * bytes_per,
                checkpoint_write_micros: epochs * model.checkpoint_pause_micros as u64,
            }
        });
    let resilience = (!plan.is_empty()).then(|| {
        let cold_starts = if warm { 0 } else { restart_count };
        ResilienceStats::from_outcomes(
            &outcomes,
            restart_count,
            cold_starts,
            quarantined,
            Vec::new(),
        )
    });

    // GC model forward run.
    let alloc_rate = processed_rate * model.alloc_per_event;
    let gc_per_sec_per_task = alloc_rate / par / model.young_bytes;
    let gc_young_count = (gc_per_sec_per_task * par * duration_s) as u64;
    let gc_young_time = (gc_young_count as f64 * model.young_pause_micros) as u64;

    // Energy: utilisation-weighted linear power over the allocated nodes.
    let nodes = cfg.slurm.nodes.max(1) as f64;
    let util = rho_engine.max(0.05);
    let watts = model.idle_watts + (model.peak_watts - model.idle_watts) * util;
    let energy_joules = watts * nodes * duration_s;

    // Synthetic timeline (seeded jitter, warmup ramp) for Fig. 8 plots.
    let store = Arc::new(MetricStore::new());
    let mut rng = Pcg32::from_master(cfg.bench.seed, 0x51);
    let samples = (duration_s as u64).clamp(2, 600);
    let mut joules = 0.0;
    let mut gc_cum = 0.0;
    let mut gc_time_cum = 0.0;
    for s in 0..samples {
        let t = (s + 1) * cfg.bench.duration_micros / samples;
        let ramp = if s == 0 { 0.7 } else { 1.0 };
        let jitter = 1.0 + (rng.f64() - 0.5) * 0.06;
        let eps = processed_rate * ramp * jitter;
        store.append("throughput.proc_out.eps", t, eps);
        store.append("throughput.driver_out.eps", t, offered * jitter);
        let lat_jitter = 1.0 + (rng.f64() - 0.5) * 0.10;
        // Latency creeps up as state/backlog accumulates over the run.
        let drift = 1.0 + 0.15 * s as f64 / samples as f64;
        store.append(
            "latency.end_to_end.p50_us",
            t,
            e2e_mean * lat_jitter * drift,
        );
        store.append(
            "latency.end_to_end.p99_us",
            t,
            e2e_mean * 2.8 * lat_jitter * drift,
        );
        gc_cum += gc_young_count as f64 / samples as f64;
        gc_time_cum += gc_young_time as f64 / samples as f64 / 1e3;
        store.append("jvm.engine.gc_young_count", t, gc_cum);
        store.append("jvm.engine.gc_young_time_ms", t, gc_time_cum);
        joules += watts * nodes * duration_s / samples as f64;
        store.append("energy.joules_total", t, joules);
    }

    // Latency summaries synthesized as tight lognormal-ish histograms.
    let mut e2e_hist = Histogram::new();
    let mut broker_hist = Histogram::new();
    let mut proc_hist = Histogram::new();
    for _ in 0..10_000 {
        let f = 1.0 + rng.f64().powi(2) * 3.0; // right-skewed tail
        e2e_hist.record((e2e_mean * f) as u64);
        broker_hist.record((broker_lat * f) as u64);
        proc_hist.record(((e2e_mean - broker_lat).max(1.0) * f * 0.8) as u64);
    }
    let latency: Vec<(MeasurementPoint, HistogramSummary)> = vec![
        (MeasurementPoint::BrokerIn, broker_hist.summary()),
        (MeasurementPoint::ProcOut, proc_hist.summary()),
        (MeasurementPoint::EndToEnd, e2e_hist.summary()),
    ];

    let summary = RunSummary {
        name: cfg.bench.name.clone(),
        pipeline: cfg.engine.pipeline_label(),
        framework: cfg.engine.framework.name(),
        parallelism: cfg.engine.parallelism,
        generated,
        processed,
        emitted,
        elapsed_micros: cfg.bench.duration_micros,
        offered_rate: offered,
        processed_rate,
        offered_bytes_rate: offered * cfg.workload.event_bytes as f64,
        latency,
        gc_young_count,
        gc_young_time_micros: gc_young_time,
        energy_joules,
        parse_failures: quarantined,
        // The analytic model carries no per-operator counters.
        operators: Vec::new(),
        batches: processed / cfg.engine.batch_size.max(1) as u64,
        recovery,
        quarantined,
        faults: outcomes,
        resilience,
        transport: None,
    };
    (summary, store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::postprocess::validate_results;

    fn cfg(rate: u64, parallelism: u32) -> BenchConfig {
        let mut c = BenchConfig::default();
        c.bench.duration_micros = 60_000_000;
        c.workload.rate = rate;
        c.engine.parallelism = parallelism;
        c.generators.max_instances = 1024;
        c
    }

    #[test]
    fn throughput_scales_linearly_until_capacity() {
        let m = SimModel::default();
        let (s1, _) = run_sim(&cfg(500_000, 16), &m);
        let (s2, _) = run_sim(&cfg(1_000_000, 16), &m);
        // Below capacity: processed == offered (Fig. 6's 1:1 line).
        assert!((s1.processed_rate - 500_000.0).abs() < 1.0);
        assert!((s2.processed_rate - 1_000_000.0).abs() < 1.0);
    }

    #[test]
    fn parallelism_plateau_matches_fig7_shape() {
        let m = SimModel::default();
        let rates: Vec<f64> = [1u32, 2, 4, 8, 16]
            .iter()
            .map(|&p| run_sim(&cfg(50_000_000, p), &m).0.processed_rate)
            .collect();
        // Monotone increase…
        assert!(rates.windows(2).all(|w| w[1] > w[0]), "{rates:?}");
        // …with diminishing returns: speedup(16/8) < speedup(2/1).
        let s21 = rates[1] / rates[0];
        let s168 = rates[4] / rates[3];
        assert!(s168 < s21, "no plateau: {rates:?}");
    }

    #[test]
    fn latency_rises_with_parallelism_at_fixed_load() {
        let m = SimModel::default();
        let lat: Vec<f64> = [1u32, 4, 16]
            .iter()
            .map(|&p| {
                run_sim(&cfg(400_000, p), &m)
                    .0
                    .latency_at(MeasurementPoint::EndToEnd)
                    .unwrap()
                    .mean
            })
            .collect();
        assert!(lat[2] > lat[0], "dispatch cost must grow: {lat:?}");
    }

    #[test]
    fn chain_specs_get_a_composed_rate_and_emission_model() {
        use crate::config::{CmpOp, PipelineSpec};
        use crate::engine::AggKind;
        let m = SimModel::default();
        let mut c = cfg(50_000_000, 8);
        c.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![
                OpSpec::Filter {
                    cmp: CmpOp::Gt,
                    value: 25.0,
                },
                OpSpec::KeyBy {
                    modulo: 64,
                    parallelism: 0,
                },
                OpSpec::window(AggKind::Mean, 2_000_000, 1_000_000),
                OpSpec::TopK {
                    k: 10,
                    parallelism: 0,
                },
                OpSpec::EmitAggregates,
            ],
        });
        let (s, _) = run_sim(&c, &m);
        assert!(s.pipeline.starts_with("chain["), "{}", s.pipeline);
        // The chain's composed service time must cost more than the bare
        // keyed pipeline it extends.
        let mut mem = cfg(50_000_000, 8);
        mem.engine.pipeline = PipelineKind::MemIntensive;
        let (sm, _) = run_sim(&mem, &m);
        assert!(s.processed_rate < sm.processed_rate);
        // topk caps the emission model at k aggregates per window.
        let windows = c.bench.duration_micros / 1_000_000;
        assert!(s.emitted <= windows * 10, "emitted {}", s.emitted);
        assert!(s.emitted > 0);
        // A keyby placed *after* the window re-keys aggregates and must
        // not narrow the modeled emitting key space.
        let mut post = cfg(50_000_000, 8);
        post.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![
                OpSpec::window(AggKind::Mean, 2_000_000, 1_000_000),
                OpSpec::KeyBy {
                    modulo: 4,
                    parallelism: 0,
                },
                OpSpec::EmitAggregates,
            ],
        });
        let (sp, _) = run_sim(&post, &m);
        let keys = post.workload.sensors.min(1024) as u64;
        assert_eq!(sp.emitted, (post.bench.duration_micros / 1_000_000) * keys);
    }

    #[test]
    fn event_time_window_costs_more_than_processing_time() {
        use crate::engine::{AggKind, LatePolicy, WindowTime};
        let m = SimModel::default();
        let spec_for = |time: WindowTime| {
            PipelineSpec {
                ops: vec![
                    OpSpec::Window {
                        agg: AggKind::Mean,
                        window_micros: 2_000_000,
                        slide_micros: 1_000_000,
                        time,
                        allowed_lateness_micros: 0,
                        late_policy: LatePolicy::Drop,
                        watermark_micros: 0,
                    },
                    OpSpec::EmitAggregates,
                ],
            }
        };
        let mut proc = cfg(50_000_000, 8);
        proc.engine.pipeline_spec = Some(spec_for(WindowTime::Processing));
        let mut event = cfg(50_000_000, 8);
        event.engine.pipeline_spec = Some(spec_for(WindowTime::Event));
        let (sp, _) = run_sim(&proc, &m);
        let (se, _) = run_sim(&event, &m);
        assert!(
            se.processed_rate < sp.processed_rate,
            "event-time bookkeeping must cost service time: {} !< {}",
            se.processed_rate,
            sp.processed_rate
        );
        // Emission cadence (slide-driven) is time-domain independent.
        assert_eq!(se.emitted, sp.emitted);
    }

    #[test]
    fn exchange_costing_prices_the_shuffle() {
        use crate::config::ExchangeMode;
        use crate::engine::AggKind;
        let m = SimModel::default();
        let keyed = |exchange: ExchangeMode| {
            let mut c = cfg(50_000_000, 8);
            c.engine.exchange = exchange;
            c.engine.pipeline_spec = Some(PipelineSpec {
                ops: vec![
                    OpSpec::KeyBy {
                        modulo: 64,
                        parallelism: 0,
                    },
                    OpSpec::window(AggKind::Mean, 2_000_000, 1_000_000),
                    OpSpec::TopK {
                        k: 10,
                        parallelism: 0,
                    },
                    OpSpec::EmitAggregates,
                ],
            });
            run_sim(&c, &m).0.processed_rate
        };
        let with = keyed(ExchangeMode::Hash);
        let without = keyed(ExchangeMode::None);
        assert!(
            with < without,
            "two exchange boundaries must cost service time: {with} !< {without}"
        );
        // The surcharge is a shuffle, not a collapse: within ~35%.
        assert!(with > without * 0.65, "{with} vs {without}");
        // A boundary-free chain prices identically either way.
        let flat = |exchange: ExchangeMode| {
            let mut c = cfg(50_000_000, 8);
            c.engine.exchange = exchange;
            c.engine.pipeline_spec = Some(PipelineSpec {
                ops: vec![OpSpec::CpuTransform, OpSpec::EmitEvents],
            });
            run_sim(&c, &m).0.processed_rate
        };
        assert_eq!(flat(ExchangeMode::Hash), flat(ExchangeMode::None));
    }

    #[test]
    fn checkpointing_is_priced_as_a_capacity_derate() {
        let m = SimModel::default();
        // Saturate the engine so the derate shows up in processed_rate.
        let base = cfg(50_000_000, 8);
        let mut ckpt = cfg(50_000_000, 8);
        ckpt.checkpoint.interval_micros = 10_000; // 4.5% duty cycle
        let (s0, _) = run_sim(&base, &m);
        let (s1, _) = run_sim(&ckpt, &m);
        assert!(
            s1.processed_rate < s0.processed_rate,
            "snapshot pauses must cost capacity: {} !< {}",
            s1.processed_rate,
            s0.processed_rate
        );
        // A pause every 10ms shaves percent, not halves.
        assert!(s1.processed_rate > s0.processed_rate * 0.90);
        // Fault-free checkpointed runs carry no recovery block.
        assert!(s1.recovery.is_none());
    }

    #[test]
    fn fault_plan_yields_a_consistent_recovery_block() {
        let m = SimModel::default();
        let mut c = cfg(1_000_000, 8);
        c.checkpoint.interval_micros = 500_000;
        c.fault.kill_after_micros = 2_000_000;
        let (s, _) = run_sim(&c, &m);
        let rec = s.recovery.expect("fault plan must produce recovery");
        assert!(!rec.cold_start);
        assert!(rec.restored_epoch >= 1);
        assert!(rec.checkpoints >= 1);
        assert!(rec.replayed_records > 0, "mid-epoch kill replays");
        assert!(
            rec.recovery_time_micros > m.restart_micros as u64,
            "recovery = restart + replay"
        );
        let v = validate_results(&s.to_json());
        assert!(v.is_empty(), "{v:?}");
        // restore off → cold start, still self-consistent.
        let mut cold = c.clone();
        cold.fault.restore = false;
        let (sc, _) = run_sim(&cold, &m);
        let rc = sc.recovery.unwrap();
        assert!(rc.cold_start);
        assert_eq!(rc.restored_epoch, 0);
        let v = validate_results(&sc.to_json());
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn fault_schedule_prices_each_heal_cycle() {
        use crate::config::FaultSpec;
        let m = SimModel::default();
        let mut c = cfg(1_000_000, 8);
        c.checkpoint.interval_micros = 500_000;
        c.fault.schedule = vec![
            FaultSpec {
                kind: FaultKind::KillTask { task: 0 },
                at_micros: 2_000_000,
                duration_micros: 0,
                seed: 0,
            },
            FaultSpec {
                kind: FaultKind::HangTask { task: 1 },
                at_micros: 10_000_000,
                duration_micros: 400_000,
                seed: 0,
            },
            FaultSpec {
                kind: FaultKind::PoisonRecords { fraction: 0.01 },
                at_micros: 20_000_000,
                duration_micros: 5_000_000,
                seed: 0,
            },
        ];
        let (s, _) = run_sim(&c, &m);
        let r = s.resilience.clone().expect("schedule must produce resilience");
        assert_eq!(r.injected, 3);
        assert_eq!(r.detected, 3);
        assert_eq!(r.healed, 3);
        assert_eq!(r.restart_count, 2);
        assert!(
            r.downtime_micros > 2 * m.restart_micros as u64,
            "two heal cycles each pay at least the restart pause"
        );
        // The kill is observed at once; the hang waits out the heartbeat
        // deadline — and the second restart pays a doubled backoff.
        let kill = &s.faults[0];
        let hang = &s.faults[1];
        assert!(hang.detect_micros() >= c.fault.heartbeat_timeout_micros);
        assert!(kill.detect_micros() < hang.detect_micros());
        assert!(hang.mttr_micros() > kill.mttr_micros());
        // Poison quarantines ~1% of five seconds of offered load, and the
        // distinct-record accounting stays conserved.
        assert!(s.quarantined > 0);
        assert_eq!(s.processed + s.quarantined, s.generated);
        let v = validate_results(&s.to_json());
        assert!(v.is_empty(), "{v:?}");
        // A restart budget of 1 leaves the hang unhealed.
        let mut strict = c.clone();
        strict.fault.max_restarts = 1;
        let (ss, _) = run_sim(&strict, &m);
        let rs = ss.resilience.unwrap();
        assert_eq!(rs.restart_count, 1);
        assert_eq!(rs.healed, 2, "kill healed, poison window closed");
        assert!(ss.faults[1].healed_at.is_none());
    }

    #[test]
    fn gc_count_scales_with_processed_volume() {
        let m = SimModel::default();
        let (a, _) = run_sim(&cfg(500_000, 8), &m);
        let (b, _) = run_sim(&cfg(4_000_000, 8), &m);
        assert!(b.gc_young_count > 4 * a.gc_young_count);
    }

    #[test]
    fn cluster_scale_reaches_paper_throughput() {
        // Table 1's 40 M ev/s aggregate: 100+ generator instances across a
        // big allocation, wide broker.
        let m = SimModel::default();
        let mut c = cfg(45_000_000, 64);
        c.broker.partitions = 32;
        c.engine.pipeline = PipelineKind::PassThrough;
        c.slurm.nodes = 16;
        let (s, _) = run_sim(&c, &m);
        assert!(
            s.offered_rate >= 40e6,
            "offered {:.1}M < 40M",
            s.offered_rate / 1e6
        );
        assert!(s.processed_rate >= 40e6);
    }

    #[test]
    fn sim_results_validate_and_have_timeline() {
        let m = SimModel::default();
        let (s, store) = run_sim(&cfg(1_000_000, 8), &m);
        let v = validate_results(&s.to_json());
        assert!(v.is_empty(), "{v:?}");
        let gc = store.get("jvm.engine.gc_young_count").unwrap();
        let vals: Vec<f64> = gc.values().collect();
        assert!(
            vals.windows(2).all(|w| w[1] >= w[0]),
            "GC counters must be cumulative"
        );
        assert!(store.get("latency.end_to_end.p50_us").is_some());
    }

    #[test]
    fn energy_scales_with_nodes_and_time() {
        let m = SimModel::default();
        let mut c1 = cfg(1_000_000, 8);
        c1.slurm.nodes = 1;
        let mut c4 = cfg(1_000_000, 8);
        c4.slurm.nodes = 4;
        let (s1, _) = run_sim(&c1, &m);
        let (s4, _) = run_sim(&c4, &m);
        assert!((s4.energy_joules / s1.energy_joules - 4.0).abs() < 0.01);
    }
}
