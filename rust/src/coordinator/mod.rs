//! Benchmark coordinator: wires generator fleet → broker → engine → broker
//! together with the full monitoring stack, runs one experiment, and
//! produces the results document.
//!
//! * [`run_wall`] — real-thread, real-time execution on this machine.
//! * [`simrun::run_sim`] — analytic execution at cluster scale in virtual
//!   time (the 630-node Barnard runs of the paper).
//!
//! Both return the same [`RunSummary`] shape, so post-processing, the
//! workflow manager, the CLI and the benches treat them uniformly — and
//! [`crate::experiment::MaxCapacityDriver`] can wrap either entry point
//! in its stepped-load escalation loop.

pub mod simrun;

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::broker::{Broker, BrokerConfig, Topic};
use crate::config::{BenchConfig, FaultKind, FaultSpec};
use crate::engine::supervisor::{backoff_micros, DEAD_LETTER_SAMPLE_CAP};
use crate::engine::{
    Checkpoint, CheckpointCoordinator, CheckpointStore, Engine, FaultOutcome, ResilienceStats,
    RunHooks, TaskMonitor,
};
use crate::jvm::JmxSampler;
use crate::metrics::{LatencyRecorder, MeasurementPoint, MetricStore, ThroughputRecorder};
use crate::pipelines::StepFactory;
use crate::runtime::RuntimeFactory;
use crate::sysmon::{ActivityModel, NodeSpec, SysmonSampler};
use crate::util::clock::{self, ClockRef};
use crate::util::histogram::{Histogram, HistogramSummary};
use crate::util::json::Json;
use crate::wgen::{Fleet, FleetReport, GeneratorConfig, Pattern};

/// What a kill-and-restore run ([`run_recovery`]) measured, reported in
/// the results document as the `recovery` block.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RecoveryStats {
    /// Kill switch flip → every restarted task ready to consume, µs.
    pub recovery_time_micros: u64,
    /// Records the killed incarnation had ingested beyond the restore
    /// point — re-read and re-processed by the restarted incarnation.
    pub replayed_records: u64,
    /// Epoch of the checkpoint restored from (0 on a cold start).
    pub restored_epoch: u64,
    /// True when no valid checkpoint survived (or `fault.restore` was
    /// off) and the engine restarted from scratch.
    pub cold_start: bool,
    /// Corrupt or truncated checkpoint files the latest-scan skipped.
    pub corrupt_skipped: u64,
    /// Committed checkpoint files across both incarnations.
    pub checkpoints: u64,
    pub checkpoint_bytes: u64,
    /// Wall time spent assembling + writing committed checkpoints, µs.
    pub checkpoint_write_micros: u64,
}

/// Everything one experiment run produced.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub name: String,
    /// Pipeline label: the kind name (`cpu`) or a `chain[...]` label for
    /// explicit operator-chain specs.
    pub pipeline: String,
    pub framework: &'static str,
    pub parallelism: u32,
    pub generated: u64,
    pub processed: u64,
    pub emitted: u64,
    pub elapsed_micros: u64,
    /// Offered load achieved by the fleet, events/second.
    pub offered_rate: f64,
    /// Engine-processed events/second.
    pub processed_rate: f64,
    pub offered_bytes_rate: f64,
    pub latency: Vec<(MeasurementPoint, HistogramSummary)>,
    pub gc_young_count: u64,
    pub gc_young_time_micros: u64,
    pub energy_joules: f64,
    pub parse_failures: u64,
    pub batches: u64,
    /// Per-operator stats merged across engine tasks, in chain order
    /// (empty for sim runs — the analytic model has no per-op counters).
    pub operators: Vec<(String, crate::pipelines::StepStats)>,
    /// Kill-and-restore measurements; `None` for fault-free runs.
    pub recovery: Option<RecoveryStats>,
    /// Malformed records quarantined on the parse path and excluded from
    /// `processed` (supervised runs; 0 elsewhere).
    pub quarantined: u64,
    /// Per-fault injection/detection/heal timelines (the `faults[]` list
    /// of results.json); empty for fault-free runs.
    pub faults: Vec<FaultOutcome>,
    /// Recovery SLO rollup of a supervised run; `None` otherwise.
    pub resilience: Option<ResilienceStats>,
    /// Wire-level counters of a distributed (`cluster.transport: tcp`)
    /// run, summed across workers; `None` for in-process runs.
    pub transport: Option<crate::net::TransportStats>,
}

impl RunSummary {
    pub fn latency_at(&self, point: MeasurementPoint) -> Option<&HistogramSummary> {
        self.latency.iter().find(|(p, _)| *p == point).map(|(_, s)| s)
    }

    /// The results.json document (checked by `postprocess::validate`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("pipeline", Json::Str(self.pipeline.to_string()));
        j.set("framework", Json::Str(self.framework.to_string()));
        j.set("parallelism", Json::Int(self.parallelism as i64));
        let mut events = Json::obj();
        events.set("generated", Json::Int(self.generated as i64));
        events.set("processed", Json::Int(self.processed as i64));
        events.set("emitted", Json::Int(self.emitted as i64));
        events.set("quarantined", Json::Int(self.quarantined as i64));
        j.set("events", events);
        let mut tp = Json::obj();
        tp.set("offered", Json::Num(self.offered_rate));
        tp.set("processed", Json::Num(self.processed_rate));
        tp.set("offered_bytes", Json::Num(self.offered_bytes_rate));
        j.set("throughput", tp);
        let mut lat = Json::obj();
        for (point, s) in &self.latency {
            if s.count == 0 {
                continue;
            }
            let mut p = Json::obj();
            p.set("mean", Json::Num(s.mean));
            p.set("p50", Json::Int(s.p50 as i64));
            p.set("p95", Json::Int(s.p95 as i64));
            p.set("p99", Json::Int(s.p99 as i64));
            p.set("max", Json::Int(s.max as i64));
            p.set("count", Json::Int(s.count as i64));
            lat.set(point.name(), p);
        }
        j.set("latency_us", lat);
        let mut gc = Json::obj();
        gc.set("young_count", Json::Int(self.gc_young_count as i64));
        gc.set(
            "young_time_ms",
            Json::Num(self.gc_young_time_micros as f64 / 1e3),
        );
        j.set("gc", gc);
        let mut energy = Json::obj();
        energy.set("joules", Json::Num(self.energy_joules));
        j.set("energy", energy);
        j.set("elapsed_us", Json::Int(self.elapsed_micros as i64));
        j.set("parse_failures", Json::Int(self.parse_failures as i64));
        j.set("batches", Json::Int(self.batches as i64));
        if let Some(r) = &self.recovery {
            let mut rec = Json::obj();
            rec.set("recovery_time_us", Json::Int(r.recovery_time_micros as i64));
            rec.set("replayed_records", Json::Int(r.replayed_records as i64));
            rec.set("restored_epoch", Json::Int(r.restored_epoch as i64));
            rec.set("cold_start", Json::Bool(r.cold_start));
            rec.set("corrupt_skipped", Json::Int(r.corrupt_skipped as i64));
            rec.set("checkpoints", Json::Int(r.checkpoints as i64));
            rec.set("checkpoint_bytes", Json::Int(r.checkpoint_bytes as i64));
            rec.set("checkpoint_write_us", Json::Int(r.checkpoint_write_micros as i64));
            j.set("recovery", rec);
        }
        if !self.faults.is_empty() {
            j.set(
                "faults",
                Json::Arr(self.faults.iter().map(|f| f.to_json()).collect()),
            );
        }
        if let Some(r) = &self.resilience {
            j.set("resilience", r.to_json());
        }
        if let Some(t) = &self.transport {
            j.set("transport", t.to_json());
        }
        // Per-operator breakdown, chain order preserved (array, not map).
        let ops: Vec<Json> = self
            .operators
            .iter()
            .map(|(name, s)| {
                let mut o = s.to_json();
                o.set("op", Json::Str(name.clone()));
                o
            })
            .collect();
        j.set("operators", Json::Arr(ops));
        j
    }
}

/// Canonical egest capture: every drained record becomes a
/// `gen_ts_micros,key,payload-hex` line, sorted before writing, so two
/// runs of the same deterministic spec can be byte-compared regardless
/// of partition interleaving or arrival order.  This is the artifact the
/// distributed equivalence suite diffs between `cluster.transport: tcp`
/// and in-process runs (`metrics.egest_dump` enables it).
#[derive(Default)]
pub struct EgestDump {
    lines: Vec<String>,
}

impl EgestDump {
    pub fn new() -> EgestDump {
        EgestDump::default()
    }

    /// Record every entry of a drained batch.
    pub fn absorb(&mut self, batch: &crate::broker::RecordBatch) {
        const HEX: &[u8; 16] = b"0123456789abcdef";
        for i in 0..batch.len() {
            let e = batch.entry(i);
            let payload = batch.payload(i);
            let mut line = String::with_capacity(24 + payload.len() * 2);
            line.push_str(&format!("{},{},", e.gen_ts_micros, e.key));
            for &byte in payload {
                line.push(HEX[(byte >> 4) as usize] as char);
                line.push(HEX[(byte & 0xf) as usize] as char);
            }
            self.lines.push(line);
        }
    }

    /// Sort and write the canonical file; loud on I/O failure.
    pub fn write(mut self, path: &str) -> Result<(), String> {
        self.lines.sort_unstable();
        let mut out = String::with_capacity(self.lines.iter().map(|l| l.len() + 1).sum());
        for l in &self.lines {
            out.push_str(l);
            out.push('\n');
        }
        std::fs::write(path, out).map_err(|e| format!("write egest dump {path}: {e}"))
    }
}

/// The shared wall-mode scaffold behind [`run_wall`] and
/// [`run_recovery`]: broker + topics, egestion drainer, engine (heaps
/// JMX-registered), interval sampler, and the generator fleet.  The
/// fleet waits for `engine_ready` before offering load and closes the
/// input topic when its run span elapses — which is what eventually
/// makes the engine phase(s) drain and return.
struct WallHarness {
    clk: ClockRef,
    store: Arc<MetricStore>,
    latency: Arc<LatencyRecorder>,
    broker: Arc<Broker>,
    in_topic: Arc<Topic>,
    out_topic: Arc<Topic>,
    engine: Engine,
    stop: Arc<AtomicBool>,
    engine_ready: Arc<AtomicU32>,
    drainer: std::thread::JoinHandle<u64>,
    sampler_stop: Arc<AtomicBool>,
    sampler: std::thread::JoinHandle<(JmxSampler, SysmonSampler, Histogram, Histogram)>,
    fleet: std::thread::JoinHandle<FleetReport>,
}

/// Everything [`WallHarness::finish`] collects after the engine phase(s).
struct WallTeardown {
    fleet: FleetReport,
    drained: u64,
    latency: Vec<(MeasurementPoint, HistogramSummary)>,
    gc_young_count: u64,
    gc_young_time_micros: u64,
    energy_joules: f64,
}

impl WallHarness {
    /// Engine deadline: the configured run span plus generous slack for
    /// pipeline compilation and final drain.
    fn engine_deadline(cfg: &BenchConfig) -> u64 {
        cfg.bench.duration_micros + cfg.bench.warmup_micros + 30_000_000
    }

    fn start(cfg: &BenchConfig) -> WallHarness {
        let clk: ClockRef = clock::wall();
        let store = Arc::new(MetricStore::new());
        let throughput = Arc::new(ThroughputRecorder::new());
        let latency = Arc::new(LatencyRecorder::new());

        let broker = Broker::new(BrokerConfig::from_section(&cfg.broker), clk.clone());
        let in_topic = broker.create_topic("ingest");
        let out_topic = broker.create_topic("egest");

        // Egestion drainer: the downstream consumer of processed results.
        let drain_group = broker.subscribe("egest", "downstream", 1);
        let dump_path = cfg.metrics.egest_dump.clone();
        let drainer = {
            let g = drain_group;
            std::thread::Builder::new()
                .name("egest-drain".into())
                .spawn(move || {
                    let mut n = 0u64;
                    let mut dump = (!dump_path.is_empty()).then(EgestDump::new);
                    loop {
                        match g.poll(0, 4096) {
                            Ok(Some(b)) => {
                                n += b.record_count() as u64;
                                if let Some(d) = dump.as_mut() {
                                    for rb in &b.batches {
                                        d.absorb(rb);
                                    }
                                }
                                g.commit(b.partition, b.next_offset);
                            }
                            Ok(None) => std::thread::sleep(std::time::Duration::from_micros(500)),
                            Err(_) => {
                                if let Some(d) = dump.take() {
                                    if let Err(e) = d.write(&dump_path) {
                                        eprintln!("[coordinator] {e}");
                                    }
                                }
                                return n;
                            }
                        }
                    }
                })
                .expect("spawn drainer")
        };

        // Engine first: its heaps register with JMX before sampling starts.
        let engine = Engine::new(cfg, clk.clone(), throughput.clone(), latency.clone());
        let mut jmx = JmxSampler::new(clk.clone(), store.clone());
        for (i, h) in engine.heaps.iter().enumerate() {
            jmx.register(&format!("engine-task-{i}"), h.clone());
        }
        let mut sysmon = SysmonSampler::new(
            clk.clone(),
            store.clone(),
            throughput.clone(),
            NodeSpec::default(),
            ActivityModel::default(),
        );

        let stop = Arc::new(AtomicBool::new(false));
        let sampler_stop = Arc::new(AtomicBool::new(false));

        // Interval sampler: throughput rates + per-interval latency timeline
        // (the Fig. 8 series) + JMX + sysmon.  ProcOut/EndToEnd histograms are
        // drained per interval for the timeline and merged into cumulative
        // copies for the whole-run summary.
        let sampler = {
            let clk = clk.clone();
            let store = store.clone();
            let tp = throughput.clone();
            let lat = latency.clone();
            let stop = sampler_stop.clone();
            let interval = cfg.metrics.sample_interval_micros.max(10_000);
            std::thread::Builder::new()
                .name("metrics-sampler".into())
                .spawn(move || {
                    let mut prev = tp.snapshot();
                    let mut prev_t = clk.now_micros();
                    let mut cum_proc = Histogram::new();
                    let mut cum_e2e = Histogram::new();
                    loop {
                        let stopping = stop.load(Ordering::Relaxed);
                        if !stopping {
                            clk.sleep_micros(interval);
                        }
                        let now = clk.now_micros();
                        let snap = tp.snapshot();
                        let dt = now.saturating_sub(prev_t).max(1);
                        for p in MeasurementPoint::ALL {
                            store.append(
                                &format!("throughput.{}.eps", p.name()),
                                now,
                                snap.rate_events(&prev, p, dt),
                            );
                            store.append(
                                &format!("throughput.{}.bps", p.name()),
                                now,
                                snap.rate_bytes(&prev, p, dt),
                            );
                        }
                        for (p, cum) in [
                            (MeasurementPoint::ProcOut, &mut cum_proc),
                            (MeasurementPoint::EndToEnd, &mut cum_e2e),
                        ] {
                            let h = lat.drain(p);
                            if !h.is_empty() {
                                store.append(&format!("latency.{}.p50_us", p.name()), now, h.p50() as f64);
                                store.append(&format!("latency.{}.p99_us", p.name()), now, h.p99() as f64);
                                store.append(&format!("latency.{}.mean_us", p.name()), now, h.mean());
                                cum.merge(&h);
                            }
                        }
                        jmx.sample();
                        sysmon.sample();
                        prev = snap;
                        prev_t = now;
                        if stopping {
                            return (jmx, sysmon, cum_proc, cum_e2e);
                        }
                    }
                })
                .expect("spawn sampler")
        };

        // Fleet in the background; it waits for every engine task to finish
        // building its pipeline step (PJRT compile) before offering load, so
        // compile time never masquerades as queueing latency.  Closes the
        // input topic when done.
        let engine_ready = Arc::new(AtomicU32::new(0));
        let fleet = {
            let broker2 = broker.clone();
            let in_topic2 = in_topic.clone();
            let clk2 = clk.clone();
            let tp = throughput.clone();
            let lat = latency.clone();
            let stop2 = stop.clone();
            let gen_cfg = GeneratorConfig::from_config(cfg);
            let workload = cfg.workload.clone();
            let duration = cfg.bench.duration_micros + cfg.bench.warmup_micros;
            let ready = engine_ready.clone();
            let parallelism = cfg.engine.parallelism;
            std::thread::Builder::new()
                .name("fleet-main".into())
                .spawn(move || {
                    let wait_start = std::time::Instant::now();
                    while ready.load(Ordering::SeqCst) < parallelism
                        && wait_start.elapsed().as_secs() < 60
                        && !stop2.load(Ordering::Relaxed)
                    {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    let fleet = Fleet::new(gen_cfg, clk2, tp, lat);
                    let report = fleet.run(&broker2, &in_topic2, duration, &stop2, |share| {
                        Pattern::from_config(&workload, share)
                    });
                    in_topic2.close();
                    report
                })
                .expect("spawn fleet")
        };

        WallHarness {
            clk,
            store,
            latency,
            broker,
            in_topic,
            out_topic,
            engine,
            stop,
            engine_ready,
            drainer,
            sampler_stop,
            sampler,
            fleet,
        }
    }

    /// Join the fleet, stop the sampler, shut the broker down, join the
    /// drainer (in that order), and fold the cumulative latency copies
    /// back into the whole-run summaries.
    fn finish(self) -> Result<WallTeardown, String> {
        let fleet = self.fleet.join().map_err(|_| "fleet panicked")?;
        self.sampler_stop.store(true, Ordering::SeqCst);
        let (jmx, sysmon, cum_proc, cum_e2e) =
            self.sampler.join().map_err(|_| "sampler panicked")?;
        self.broker.shutdown();
        let drained = self.drainer.join().map_err(|_| "drainer panicked")?;

        // Whole-run latency summaries: cumulative copies for the drained
        // points, live recorder for the rest.
        let latency: Vec<(MeasurementPoint, HistogramSummary)> = MeasurementPoint::ALL
            .iter()
            .map(|&p| {
                let mut h = self.latency.merged(p);
                match p {
                    MeasurementPoint::ProcOut => h.merge(&cum_proc),
                    MeasurementPoint::EndToEnd => h.merge(&cum_e2e),
                    _ => {}
                }
                (p, h.summary())
            })
            .collect();

        let (gc_young_count, gc_young_time_micros) = jmx.aggregate_young();
        Ok(WallTeardown {
            fleet,
            drained,
            latency,
            gc_young_count,
            gc_young_time_micros,
            energy_joules: sysmon.joules_total(),
        })
    }
}

/// Run one experiment in wall mode. Returns the summary and the metric
/// store (the timeline series behind the Fig. 8-style plots).
pub fn run_wall(
    cfg: &BenchConfig,
    runtime_factory: Option<RuntimeFactory>,
) -> Result<(RunSummary, Arc<MetricStore>), String> {
    let h = WallHarness::start(cfg);

    // Engine runs on this thread; exits when the input closes and drains.
    let engine_report = h.engine.run(
        &h.broker,
        "ingest",
        &h.out_topic,
        &h.stop,
        WallHarness::engine_deadline(cfg),
        runtime_factory,
        Some(h.engine_ready.clone()),
    )?;

    let store = h.store.clone();
    let t = h.finish()?;
    let summary = RunSummary {
        name: cfg.bench.name.clone(),
        pipeline: cfg.engine.pipeline_label(),
        framework: cfg.engine.framework.name(),
        parallelism: cfg.engine.parallelism,
        generated: t.fleet.events,
        processed: engine_report.events_in,
        emitted: t.drained,
        elapsed_micros: t.fleet.elapsed_micros,
        offered_rate: t.fleet.rate_events,
        processed_rate: engine_report.rate_events,
        offered_bytes_rate: t.fleet.rate_bytes,
        latency: t.latency,
        gc_young_count: t.gc_young_count,
        gc_young_time_micros: t.gc_young_time_micros,
        energy_joules: t.energy_joules,
        parse_failures: engine_report.parse_failures,
        batches: engine_report.batches,
        operators: engine_report.operators.clone(),
        recovery: None,
        quarantined: 0,
        faults: Vec::new(),
        resilience: None,
        transport: None,
    };
    Ok((summary, store))
}

/// Chaos-schedule state shared across every engine incarnation of one
/// supervised run: the injection cursor and per-fault timelines survive
/// restarts, so a single `fault.schedule` spans the whole run.
struct ChaosState {
    /// Clock µs of "all tasks ready" in the first incarnation — the
    /// schedule's t=0 (`FaultSpec::at_micros` offsets from here).  0
    /// until armed.
    origin_micros: AtomicU64,
    /// Index of the next plan entry to inject.
    cursor: AtomicUsize,
    outcomes: Mutex<Vec<FaultOutcome>>,
    /// Active partition stalls: `(plan index, partition, release-at µs)`.
    stalls: Mutex<Vec<(usize, u32, u64)>>,
}

/// Per-incarnation chaos watchdog: arms the schedule at all-ready,
/// injects due faults, releases timed partition stalls, and declares
/// tasks whose heartbeat went stale hung (tearing the incarnation down
/// via the kill switch).  Exits when `done` is flagged, releasing any
/// stall still held — a transient broker fault never outlives its
/// watchdog.
#[allow(clippy::too_many_arguments)]
fn spawn_chaos_watchdog(
    clk: ClockRef,
    state: Arc<ChaosState>,
    plan: Arc<Vec<FaultSpec>>,
    in_topic: Arc<Topic>,
    monitor: Arc<TaskMonitor>,
    kill: Arc<AtomicBool>,
    ready: Arc<AtomicU32>,
    parallelism: u32,
    heartbeat_timeout: u64,
    done: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("chaos-watchdog".into())
        .spawn(move || loop {
            let now = clk.now_micros();
            let finished = done.load(Ordering::SeqCst);
            if state.origin_micros.load(Ordering::SeqCst) == 0
                && ready.load(Ordering::SeqCst) >= parallelism
            {
                let _ = state
                    .origin_micros
                    .compare_exchange(0, now, Ordering::SeqCst, Ordering::SeqCst);
            }
            // Release stalls whose hold elapsed — and all of them when the
            // incarnation ends (during a teardown the engine is down
            // anyway, so clearing broker faults is part of the restart).
            {
                let mut stalls = state.stalls.lock().expect("chaos stalls");
                stalls.retain(|&(idx, p, until)| {
                    if finished || now >= until {
                        in_topic.partition(p).set_stalled(false);
                        let mut o = state.outcomes.lock().expect("chaos outcomes");
                        if o[idx].healed_at.is_none() {
                            o[idx].healed_at = Some(now);
                        }
                        false
                    } else {
                        true
                    }
                });
            }
            if finished {
                return;
            }
            let origin = state.origin_micros.load(Ordering::SeqCst);
            if origin == 0 {
                std::thread::sleep(std::time::Duration::from_micros(500));
                continue;
            }
            let t = now.saturating_sub(origin);
            // Inject every due fault.  While a teardown is in flight the
            // cursor stays put: the next incarnation's watchdog picks the
            // remaining entries up.
            while !kill.load(Ordering::SeqCst) {
                let idx = state.cursor.load(Ordering::SeqCst);
                if idx >= plan.len() || plan[idx].at_micros > t {
                    break;
                }
                let f = plan[idx].clone();
                let mut new_stall = None;
                {
                    let mut o = state.outcomes.lock().expect("chaos outcomes");
                    o[idx].injected_at = Some(now);
                    match f.kind {
                        FaultKind::KillTask { .. } => {
                            // Whole-incarnation crash (process-death
                            // model); detection is the supervisor
                            // observing the engine die.
                            kill.store(true, Ordering::SeqCst);
                        }
                        FaultKind::HangTask { task } => {
                            // The task stops polling AND heartbeating;
                            // only the heartbeat deadline can notice.
                            monitor.inject_hang(task, now + f.duration_micros);
                        }
                        FaultKind::StallPartition { partition } => {
                            // Supervisor-tracked degradation: injected and
                            // observed in the same breath.
                            o[idx].detected_at = Some(now);
                            in_topic.partition(partition).set_stalled(true);
                            new_stall = Some((idx, partition, now + f.duration_micros));
                        }
                        FaultKind::PoisonRecords { .. } => {
                            // The generator corrupts payloads on its own
                            // seeded clock; the timeline entry only tracks
                            // the window.
                        }
                        FaultKind::PeerDisconnect { .. } => {
                            // Detection-only: distributed workers append
                            // this when a TCP peer dies; it is never
                            // scheduled, so the injector has nothing to do.
                        }
                    }
                }
                if let Some(s) = new_stall {
                    state.stalls.lock().expect("chaos stalls").push(s);
                }
                state.cursor.store(idx + 1, Ordering::SeqCst);
            }
            // Close finite poison windows.
            {
                let mut o = state.outcomes.lock().expect("chaos outcomes");
                for oc in o.iter_mut() {
                    if matches!(oc.spec.kind, FaultKind::PoisonRecords { .. })
                        && oc.spec.duration_micros > 0
                        && oc.healed_at.is_none()
                        && oc.injected_at.is_some_and(|i| now >= i + oc.spec.duration_micros)
                    {
                        oc.healed_at = Some(now);
                    }
                }
            }
            // Heartbeat deadline: a live task that stopped beating is
            // hung — tear the incarnation down for a supervised restart.
            if !kill.load(Ordering::SeqCst) {
                if let Some(task) = monitor.stale_task(now, heartbeat_timeout) {
                    let mut o = state.outcomes.lock().expect("chaos outcomes");
                    if let Some(oc) = o.iter_mut().find(|oc| {
                        oc.injected_at.is_some()
                            && oc.detected_at.is_none()
                            && matches!(oc.spec.kind, FaultKind::HangTask { task: h } if h == task)
                    }) {
                        oc.detected_at = Some(now);
                    }
                    kill.store(true, Ordering::SeqCst);
                }
            }
            std::thread::sleep(std::time::Duration::from_micros(500));
        })
        .expect("spawn chaos watchdog")
}

/// Run one experiment in wall mode under the configured fault plan: the
/// declarative `fault.schedule` (plus the legacy `kill_after` single-kill
/// form) is injected by a chaos watchdog while a supervisor loop keeps
/// the engine alive.  Dead incarnations are detected by observing the
/// engine die, hung ones by heartbeat deadline; either way the
/// incarnation is torn down and restarted from the newest valid
/// checkpoint with exponential backoff, bounded by `fault.max_restarts`.
/// A missing or wholly corrupt checkpoint degrades to a counted cold
/// start.  The generator fleet keeps offering load across every outage,
/// so the backlog that accumulates while the engine is down is replayed
/// and drained by the healed incarnation — no external orchestration.
///
/// The summary merges all incarnations: `processed` counts distinct
/// parseable records (replays and quarantined poison subtracted), the
/// `recovery` block keeps its kill-and-restore semantics (recovery time
/// = first restart fault's injection → all-ready, replay volume,
/// checkpoint cost), and the `faults[]` / `resilience` blocks report the
/// per-fault timelines and the SLO rollup.  `emitted` stays the raw
/// egestion count, which can exceed a fault-free run's — records
/// processed between the last durable snapshot and a crash are emitted
/// twice (at-least-once egestion; exactly-once applies to state, not to
/// the output topic).
pub fn run_recovery(
    cfg: &BenchConfig,
    runtime_factory: Option<RuntimeFactory>,
) -> Result<(RunSummary, Arc<MetricStore>), String> {
    if !cfg.fault.enabled() {
        return run_wall(cfg, runtime_factory);
    }
    let plan = Arc::new(cfg.fault.plan());
    let h = WallHarness::start(cfg);
    let clk = h.clk.clone();
    let parallelism = cfg.engine.parallelism;
    let factory = Arc::new(StepFactory::new(cfg, runtime_factory));
    let deadline = WallHarness::engine_deadline(cfg);
    let ckpt_dir = cfg.checkpoint_dir();
    let retain = cfg.checkpoint.retain;
    // One epoch origin for the whole run: every incarnation's coordinator
    // continues the checkpoint numbering, never colliding with (or
    // sorting older than) files already on disk.
    let epoch_origin = clk.now_micros();
    let state = Arc::new(ChaosState {
        origin_micros: AtomicU64::new(0),
        cursor: AtomicUsize::new(0),
        outcomes: Mutex::new(plan.iter().cloned().map(FaultOutcome::new).collect()),
        stalls: Mutex::new(Vec::new()),
    });

    let mut restored: Option<Checkpoint> = None;
    let mut incarnation: u32 = 0;
    let mut restart_count: u32 = 0;
    let mut cold_starts: u32 = 0;
    let mut total_events_in = 0u64;
    let mut total_replayed = 0u64;
    let mut parse_failures = 0u64;
    let mut batches = 0u64;
    let mut corrupt_skipped = 0u64;
    // Absolute intake at the current restore point; checkpointed
    // `events_in` is absolute across incarnations (tasks carry the
    // restored count forward), so durable/replay math stays exact under
    // multiple restarts.
    let mut durable_abs = 0u64;
    // Same absolute-count trick for quarantined records: replayed poison
    // is re-quarantined by the restored incarnation, so the overlap is
    // subtracted to keep the distinct poison count exact.
    let mut durable_parse = 0u64;
    let mut replayed_parse = 0u64;
    let mut first_restore: Option<(u64, bool)> = None;
    let mut ckpt_committed = 0u64;
    let mut ckpt_bytes = 0u64;
    let mut ckpt_write = 0u64;
    let mut dead_letters: Vec<String> = Vec::new();
    let mut operators: Vec<(String, crate::pipelines::StepStats)> = Vec::new();

    loop {
        let monitor = Arc::new(TaskMonitor::new(parallelism));
        let kill = Arc::new(AtomicBool::new(false));
        let coord = cfg.checkpoint.enabled().then(|| {
            Arc::new(CheckpointCoordinator::new(
                CheckpointStore::new(ckpt_dir.as_str(), retain),
                parallelism as usize,
                cfg.checkpoint.interval_micros,
                epoch_origin,
            ))
        });
        let ready = if incarnation == 0 {
            h.engine_ready.clone() // the fleet gates its load offer on this one
        } else {
            Arc::new(AtomicU32::new(0))
        };
        let done = Arc::new(AtomicBool::new(false));
        // Healer: the moment this restarted incarnation reaches
        // all-ready, every fault detected before it launched is healed.
        let healer = (incarnation > 0).then(|| {
            let clk = clk.clone();
            let ready = ready.clone();
            let stop = h.stop.clone();
            let state = state.clone();
            let done = done.clone();
            let cutoff = clk.now_micros();
            std::thread::Builder::new()
                .name("chaos-healer".into())
                .spawn(move || {
                    let t0 = std::time::Instant::now();
                    while ready.load(Ordering::SeqCst) < parallelism
                        && t0.elapsed().as_secs() < 60
                        && !stop.load(Ordering::Relaxed)
                        && !done.load(Ordering::SeqCst)
                    {
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                    let now = clk.now_micros();
                    let mut o = state.outcomes.lock().expect("chaos outcomes");
                    for oc in o.iter_mut() {
                        if oc.spec.needs_restart()
                            && oc.healed_at.is_none()
                            && oc.detected_at.is_some_and(|d| d <= cutoff)
                        {
                            oc.healed_at = Some(now);
                        }
                    }
                })
                .expect("spawn chaos healer")
        });
        let watchdog = spawn_chaos_watchdog(
            clk.clone(),
            state.clone(),
            plan.clone(),
            h.in_topic.clone(),
            monitor.clone(),
            kill.clone(),
            ready.clone(),
            parallelism,
            cfg.fault.heartbeat_timeout_micros,
            done.clone(),
        );
        let res = h.engine.run_with_hooks(
            &h.broker,
            "ingest",
            &h.out_topic,
            &h.stop,
            deadline,
            factory.clone(),
            Some(ready.clone()),
            RunHooks {
                checkpoint: coord.clone(),
                kill: Some(kill.clone()),
                restore_from: restored.take().map(Arc::new),
                monitor: Some(monitor.clone()),
            },
        );
        done.store(true, Ordering::SeqCst);
        watchdog.join().map_err(|_| "chaos watchdog panicked")?;
        let r = match res {
            Ok(r) => r,
            Err(e) => {
                h.stop.store(true, Ordering::SeqCst);
                h.broker.shutdown();
                if let Some(hl) = healer {
                    let _ = hl.join();
                }
                return Err(e);
            }
        };
        if let Some(hl) = healer {
            hl.join().map_err(|_| "chaos healer panicked")?;
        }
        if let Some(c) = &coord {
            let s = c.stats();
            ckpt_committed += s.committed;
            ckpt_bytes += s.bytes;
            ckpt_write += s.write_micros;
        }
        total_events_in += r.events_in;
        parse_failures += r.parse_failures;
        batches += r.batches;
        for dl in &r.dead_letters {
            if dead_letters.len() >= DEAD_LETTER_SAMPLE_CAP {
                break;
            }
            dead_letters.push(dl.clone());
        }
        // Torn-down tasks lose their in-memory operator counters; the
        // last incarnation's are complete from its restore point onward.
        operators = r.operators.clone();
        let abs_highwater = durable_abs + r.events_in;
        let abs_parse = durable_parse + r.parse_failures;
        if !kill.load(Ordering::SeqCst) {
            break; // input drained and the engine exited on its own
        }

        // Teardown: death observed.  Kills are detected here (the
        // supervisor noticing the engine die); hangs were already stamped
        // by the watchdog's heartbeat deadline.
        let now = clk.now_micros();
        {
            let mut o = state.outcomes.lock().expect("chaos outcomes");
            for oc in o.iter_mut() {
                if oc.spec.needs_restart()
                    && oc.injected_at.is_some()
                    && oc.detected_at.is_none()
                {
                    oc.detected_at = Some(now);
                }
            }
        }
        if restart_count >= cfg.fault.max_restarts {
            h.stop.store(true, Ordering::SeqCst);
            h.broker.shutdown();
            return Err(format!(
                "supervisor: fault.max_restarts ({}) exhausted — engine still failing",
                cfg.fault.max_restarts
            ));
        }
        restart_count += 1;

        // Warm-restore scan: corrupt or truncated files are skipped
        // (counted); a missing checkpoint — or `restore: false` — goes
        // cold, and the fresh consumer group replays from the earliest
        // retained offsets (the pruned prefix below the low watermark is
        // gone and cannot be replayed).
        let scan = CheckpointStore::new(ckpt_dir.as_str(), retain).latest();
        corrupt_skipped += scan.skipped.len() as u64;
        let next = if cfg.fault.restore { scan.checkpoint } else { None };
        let next_durable = match &next {
            Some(c) => c.events_in(),
            None => (0..h.in_topic.partition_count())
                .map(|p| h.in_topic.partition(p).low_watermark())
                .sum(),
        };
        if next.is_none() {
            cold_starts += 1;
        }
        if first_restore.is_none() {
            first_restore = Some((next.as_ref().map_or(0, |c| c.epoch), next.is_none()));
        }
        total_replayed += abs_highwater.saturating_sub(next_durable);
        durable_abs = next_durable;
        // Cold starts re-read from the partitions' low watermarks, which
        // for a group that never committed is the log head: every prior
        // quarantine is about to repeat, so the durable parse baseline
        // resets with the intake baseline.
        let next_durable_parse = next.as_ref().map_or(0, |c| c.parse_failures());
        replayed_parse += abs_parse.saturating_sub(next_durable_parse);
        durable_parse = next_durable_parse;
        restored = next;
        incarnation += 1;

        // Exponential backoff before the restart (doubles per attempt).
        let pause = backoff_micros(cfg.fault.backoff_micros, restart_count - 1);
        if pause > 0 {
            std::thread::sleep(std::time::Duration::from_micros(pause));
        }
    }

    // Distinct quarantine: every incarnation's parse failures minus the
    // re-quarantined replay overlap (exact — checkpoints carry absolute
    // parse counts alongside absolute intake).
    let quarantined = parse_failures.saturating_sub(replayed_parse);

    // Final poison bookkeeping: a whole-run window heals when the run
    // ends, and quarantined records mean the poison was caught on the
    // parse path — detection is effectively per-record and immediate.
    {
        let mut o = state.outcomes.lock().expect("chaos outcomes");
        let now = clk.now_micros();
        for oc in o.iter_mut() {
            if matches!(oc.spec.kind, FaultKind::PoisonRecords { .. }) && oc.injected_at.is_some()
            {
                if oc.healed_at.is_none() {
                    oc.healed_at = Some(now);
                }
                if quarantined > 0 && oc.detected_at.is_none() {
                    oc.detected_at = oc.injected_at;
                }
            }
        }
    }
    let outcomes = state.outcomes.lock().expect("chaos outcomes").clone();
    // Legacy kill-and-restore stats, preserved for schedules containing a
    // restart fault: recovery time is the first such fault's injection →
    // back-to-all-ready span.
    let recovery = plan.iter().any(|f| f.needs_restart()).then(|| {
        let first = outcomes
            .iter()
            .find(|o| o.spec.needs_restart() && o.injected_at.is_some());
        RecoveryStats {
            recovery_time_micros: first.map_or(0, |o| o.mttr_micros()),
            replayed_records: total_replayed,
            restored_epoch: first_restore.map_or(0, |(e, _)| e),
            cold_start: first_restore.is_some_and(|(_, c)| c),
            corrupt_skipped,
            checkpoints: ckpt_committed,
            checkpoint_bytes: ckpt_bytes,
            checkpoint_write_micros: ckpt_write,
        }
    });
    let resilience = ResilienceStats::from_outcomes(
        &outcomes,
        restart_count as u64,
        cold_starts as u64,
        quarantined,
        dead_letters,
    );

    let store = h.store.clone();
    let t = h.finish()?;
    // Distinct records processed: every incarnation's intake minus the
    // replayed overlap, minus the quarantined poison.
    let distinct = total_events_in.saturating_sub(total_replayed);
    let processed = distinct.saturating_sub(quarantined);
    let elapsed = t.fleet.elapsed_micros.max(1);
    let summary = RunSummary {
        name: cfg.bench.name.clone(),
        pipeline: cfg.engine.pipeline_label(),
        framework: cfg.engine.framework.name(),
        parallelism: cfg.engine.parallelism,
        generated: t.fleet.events,
        processed,
        emitted: t.drained,
        elapsed_micros: t.fleet.elapsed_micros,
        offered_rate: t.fleet.rate_events,
        processed_rate: processed as f64 * 1e6 / elapsed as f64,
        offered_bytes_rate: t.fleet.rate_bytes,
        latency: t.latency,
        gc_young_count: t.gc_young_count,
        gc_young_time_micros: t.gc_young_time_micros,
        energy_joules: t.energy_joules,
        parse_failures: quarantined,
        batches,
        operators,
        recovery,
        quarantined,
        faults: outcomes,
        resilience: Some(resilience),
        transport: None,
    };
    Ok((summary, store))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Framework, PipelineKind};
    use crate::postprocess::validate_results;

    fn quick_cfg() -> BenchConfig {
        let mut cfg = BenchConfig::default();
        cfg.bench.name = "coord-test".into();
        cfg.bench.duration_micros = 700_000;
        cfg.bench.warmup_micros = 0;
        cfg.workload.rate = 60_000;
        cfg.workload.sensors = 128;
        cfg.engine.parallelism = 2;
        cfg.engine.use_hlo = false;
        cfg.engine.batch_size = 256;
        cfg.metrics.sample_interval_micros = 100_000;
        cfg
    }

    #[test]
    fn wall_run_produces_consistent_summary() {
        let cfg = quick_cfg();
        let (summary, store) = run_wall(&cfg, None).unwrap();
        assert!(summary.generated > 10_000, "generated={}", summary.generated);
        assert_eq!(summary.processed, summary.generated, "engine must drain");
        assert_eq!(summary.emitted, summary.processed);
        assert_eq!(summary.parse_failures, 0);
        // Timeline series exist.
        assert!(store.get("throughput.driver_out.eps").is_some());
        assert!(store.get("jvm.engine-task-0.gc_young_count").is_some());
        assert!(store.get("energy.joules_total").is_some());
        // Latency recorded at the key points.
        let e2e = summary.latency_at(MeasurementPoint::EndToEnd).unwrap();
        assert_eq!(e2e.count, summary.processed);
        assert!(e2e.p50 > 0);
        // Results doc passes validation.
        let violations = validate_results(&summary.to_json());
        assert!(violations.is_empty(), "{violations:?}");
        // Per-operator stats survive into the results document.
        let names: Vec<&str> = summary.operators.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["cpu_transform", "emit_events"]);
        assert_eq!(summary.operators[0].1.events_in, summary.processed);
        let ops = summary.to_json();
        let ops = ops.get("operators").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(ops.len(), 2);
        assert_eq!(ops[0].get("op").and_then(|v| v.as_str()), Some("cpu_transform"));
    }

    #[test]
    fn recovery_run_replays_and_conserves_distinct_records() {
        let mut cfg = quick_cfg();
        cfg.bench.name = "coord-recovery".into();
        cfg.bench.duration_micros = 1_500_000;
        cfg.checkpoint.interval_micros = 150_000;
        cfg.checkpoint.dir = std::env::temp_dir()
            .join(format!("sprobench-coord-recovery-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        cfg.fault.kill_after_micros = 500_000;
        cfg.fault.kill_task = 1;
        std::fs::remove_dir_all(&cfg.checkpoint.dir).ok();
        let (summary, _) = run_recovery(&cfg, None).unwrap();
        std::fs::remove_dir_all(&cfg.checkpoint.dir).ok();
        let rec = summary.recovery.expect("fault run must report recovery");
        assert!(rec.recovery_time_micros > 0, "kill→ready must take time");
        assert!(!rec.cold_start, "checkpoints were enabled: {rec:?}");
        assert!(rec.checkpoints > 0, "no checkpoint committed before kill");
        assert!(rec.checkpoint_bytes > 0);
        assert!(rec.replayed_records > 0, "kill mid-epoch must force replay");
        assert_eq!(rec.corrupt_skipped, 0);
        // Exactly-once accounting: replays are subtracted, so distinct
        // processed records equal the offered load.
        assert_eq!(summary.processed, summary.generated, "{rec:?}");
        // At-least-once egestion: nothing the engine emitted is lost.
        assert!(summary.emitted >= summary.processed);
        let j = summary.to_json();
        let rj = j.get("recovery").expect("recovery block in results.json");
        assert!(rj.get("recovery_time_us").and_then(|v| v.as_i64()).unwrap() > 0);
        assert_eq!(rj.get("cold_start").and_then(|v| v.as_bool()), Some(false));
        let violations = validate_results(&j);
        assert!(violations.is_empty(), "{violations:?}");
    }

    #[test]
    fn recovery_without_fault_plan_is_a_plain_wall_run() {
        let mut cfg = quick_cfg();
        cfg.bench.duration_micros = 400_000;
        let (summary, _) = run_recovery(&cfg, None).unwrap();
        assert!(summary.recovery.is_none(), "no fault → no recovery block");
    }

    #[test]
    fn spark_personality_has_higher_latency_than_flink() {
        let mut flink = quick_cfg();
        flink.engine.framework = Framework::Flink;
        let mut spark = quick_cfg();
        spark.engine.framework = Framework::Spark;
        spark.engine.microbatch_micros = 150_000;
        let (sf, _) = run_wall(&flink, None).unwrap();
        let (ss, _) = run_wall(&spark, None).unwrap();
        let lf = sf.latency_at(MeasurementPoint::EndToEnd).unwrap().p50;
        let ls = ss.latency_at(MeasurementPoint::EndToEnd).unwrap().p50;
        assert!(
            ls > lf,
            "micro-batching must cost latency: spark p50 {ls} <= flink p50 {lf}"
        );
    }

    #[test]
    fn mem_pipeline_summary_validates() {
        let mut cfg = quick_cfg();
        cfg.engine.pipeline = PipelineKind::MemIntensive;
        cfg.engine.window_micros = 300_000;
        cfg.engine.slide_micros = 100_000;
        let (summary, _) = run_wall(&cfg, None).unwrap();
        assert!(summary.emitted > 0, "window aggregates must flow");
        let violations = validate_results(&summary.to_json());
        assert!(violations.is_empty(), "{violations:?}");
    }
}
