//! Benchmark coordinator: wires generator fleet → broker → engine → broker
//! together with the full monitoring stack, runs one experiment, and
//! produces the results document.
//!
//! * [`run_wall`] — real-thread, real-time execution on this machine.
//! * [`simrun::run_sim`] — analytic execution at cluster scale in virtual
//!   time (the 630-node Barnard runs of the paper).
//!
//! Both return the same [`RunSummary`] shape, so post-processing, the
//! workflow manager, the CLI and the benches treat them uniformly — and
//! [`crate::experiment::MaxCapacityDriver`] can wrap either entry point
//! in its stepped-load escalation loop.

pub mod simrun;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::broker::{Broker, BrokerConfig};
use crate::config::BenchConfig;
use crate::engine::Engine;
use crate::jvm::JmxSampler;
use crate::metrics::{LatencyRecorder, MeasurementPoint, MetricStore, ThroughputRecorder};
use crate::runtime::RuntimeFactory;
use crate::sysmon::{ActivityModel, NodeSpec, SysmonSampler};
use crate::util::clock::{self, ClockRef};
use crate::util::histogram::{Histogram, HistogramSummary};
use crate::util::json::Json;
use crate::wgen::{Fleet, GeneratorConfig, Pattern};

/// Everything one experiment run produced.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub name: String,
    /// Pipeline label: the kind name (`cpu`) or a `chain[...]` label for
    /// explicit operator-chain specs.
    pub pipeline: String,
    pub framework: &'static str,
    pub parallelism: u32,
    pub generated: u64,
    pub processed: u64,
    pub emitted: u64,
    pub elapsed_micros: u64,
    /// Offered load achieved by the fleet, events/second.
    pub offered_rate: f64,
    /// Engine-processed events/second.
    pub processed_rate: f64,
    pub offered_bytes_rate: f64,
    pub latency: Vec<(MeasurementPoint, HistogramSummary)>,
    pub gc_young_count: u64,
    pub gc_young_time_micros: u64,
    pub energy_joules: f64,
    pub parse_failures: u64,
    pub batches: u64,
    /// Per-operator stats merged across engine tasks, in chain order
    /// (empty for sim runs — the analytic model has no per-op counters).
    pub operators: Vec<(String, crate::pipelines::StepStats)>,
}

impl RunSummary {
    pub fn latency_at(&self, point: MeasurementPoint) -> Option<&HistogramSummary> {
        self.latency.iter().find(|(p, _)| *p == point).map(|(_, s)| s)
    }

    /// The results.json document (checked by `postprocess::validate`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("pipeline", Json::Str(self.pipeline.to_string()));
        j.set("framework", Json::Str(self.framework.to_string()));
        j.set("parallelism", Json::Int(self.parallelism as i64));
        let mut events = Json::obj();
        events.set("generated", Json::Int(self.generated as i64));
        events.set("processed", Json::Int(self.processed as i64));
        events.set("emitted", Json::Int(self.emitted as i64));
        j.set("events", events);
        let mut tp = Json::obj();
        tp.set("offered", Json::Num(self.offered_rate));
        tp.set("processed", Json::Num(self.processed_rate));
        tp.set("offered_bytes", Json::Num(self.offered_bytes_rate));
        j.set("throughput", tp);
        let mut lat = Json::obj();
        for (point, s) in &self.latency {
            if s.count == 0 {
                continue;
            }
            let mut p = Json::obj();
            p.set("mean", Json::Num(s.mean));
            p.set("p50", Json::Int(s.p50 as i64));
            p.set("p95", Json::Int(s.p95 as i64));
            p.set("p99", Json::Int(s.p99 as i64));
            p.set("max", Json::Int(s.max as i64));
            p.set("count", Json::Int(s.count as i64));
            lat.set(point.name(), p);
        }
        j.set("latency_us", lat);
        let mut gc = Json::obj();
        gc.set("young_count", Json::Int(self.gc_young_count as i64));
        gc.set(
            "young_time_ms",
            Json::Num(self.gc_young_time_micros as f64 / 1e3),
        );
        j.set("gc", gc);
        let mut energy = Json::obj();
        energy.set("joules", Json::Num(self.energy_joules));
        j.set("energy", energy);
        j.set("elapsed_us", Json::Int(self.elapsed_micros as i64));
        j.set("parse_failures", Json::Int(self.parse_failures as i64));
        j.set("batches", Json::Int(self.batches as i64));
        // Per-operator breakdown, chain order preserved (array, not map).
        let ops: Vec<Json> = self
            .operators
            .iter()
            .map(|(name, s)| {
                let mut o = s.to_json();
                o.set("op", Json::Str(name.clone()));
                o
            })
            .collect();
        j.set("operators", Json::Arr(ops));
        j
    }
}

/// Run one experiment in wall mode. Returns the summary and the metric
/// store (the timeline series behind the Fig. 8-style plots).
pub fn run_wall(
    cfg: &BenchConfig,
    runtime_factory: Option<RuntimeFactory>,
) -> Result<(RunSummary, Arc<MetricStore>), String> {
    let clk: ClockRef = clock::wall();
    let store = Arc::new(MetricStore::new());
    let throughput = Arc::new(ThroughputRecorder::new());
    let latency = Arc::new(LatencyRecorder::new());

    let broker = Broker::new(BrokerConfig::from_section(&cfg.broker), clk.clone());
    let in_topic = broker.create_topic("ingest");
    let out_topic = broker.create_topic("egest");

    // Egestion drainer: the downstream consumer of processed results.
    let drain_group = broker.subscribe("egest", "downstream", 1);
    let drainer = {
        let g = drain_group;
        std::thread::Builder::new()
            .name("egest-drain".into())
            .spawn(move || {
                let mut n = 0u64;
                loop {
                    match g.poll(0, 4096) {
                        Ok(Some(b)) => {
                            n += b.record_count() as u64;
                            g.commit(b.partition, b.next_offset);
                        }
                        Ok(None) => std::thread::sleep(std::time::Duration::from_micros(500)),
                        Err(_) => return n,
                    }
                }
            })
            .expect("spawn drainer")
    };

    // Engine first: its heaps register with JMX before sampling starts.
    let engine = Engine::new(cfg, clk.clone(), throughput.clone(), latency.clone());
    let mut jmx = JmxSampler::new(clk.clone(), store.clone());
    for (i, h) in engine.heaps.iter().enumerate() {
        jmx.register(&format!("engine-task-{i}"), h.clone());
    }
    let mut sysmon = SysmonSampler::new(
        clk.clone(),
        store.clone(),
        throughput.clone(),
        NodeSpec::default(),
        ActivityModel::default(),
    );

    let stop = Arc::new(AtomicBool::new(false));
    let sampler_stop = Arc::new(AtomicBool::new(false));

    // Interval sampler: throughput rates + per-interval latency timeline
    // (the Fig. 8 series) + JMX + sysmon.  ProcOut/EndToEnd histograms are
    // drained per interval for the timeline and merged into cumulative
    // copies for the whole-run summary.
    let sampler = {
        let clk = clk.clone();
        let store = store.clone();
        let tp = throughput.clone();
        let lat = latency.clone();
        let stop = sampler_stop.clone();
        let interval = cfg.metrics.sample_interval_micros.max(10_000);
        std::thread::Builder::new()
            .name("metrics-sampler".into())
            .spawn(move || {
                let mut prev = tp.snapshot();
                let mut prev_t = clk.now_micros();
                let mut cum_proc = Histogram::new();
                let mut cum_e2e = Histogram::new();
                loop {
                    let stopping = stop.load(Ordering::Relaxed);
                    if !stopping {
                        clk.sleep_micros(interval);
                    }
                    let now = clk.now_micros();
                    let snap = tp.snapshot();
                    let dt = now.saturating_sub(prev_t).max(1);
                    for p in MeasurementPoint::ALL {
                        store.append(
                            &format!("throughput.{}.eps", p.name()),
                            now,
                            snap.rate_events(&prev, p, dt),
                        );
                        store.append(
                            &format!("throughput.{}.bps", p.name()),
                            now,
                            snap.rate_bytes(&prev, p, dt),
                        );
                    }
                    for (p, cum) in [
                        (MeasurementPoint::ProcOut, &mut cum_proc),
                        (MeasurementPoint::EndToEnd, &mut cum_e2e),
                    ] {
                        let h = lat.drain(p);
                        if !h.is_empty() {
                            store.append(&format!("latency.{}.p50_us", p.name()), now, h.p50() as f64);
                            store.append(&format!("latency.{}.p99_us", p.name()), now, h.p99() as f64);
                            store.append(&format!("latency.{}.mean_us", p.name()), now, h.mean());
                            cum.merge(&h);
                        }
                    }
                    jmx.sample();
                    sysmon.sample();
                    prev = snap;
                    prev_t = now;
                    if stopping {
                        return (jmx, sysmon, cum_proc, cum_e2e);
                    }
                }
            })
            .expect("spawn sampler")
    };

    // Fleet in the background; it waits for every engine task to finish
    // building its pipeline step (PJRT compile) before offering load, so
    // compile time never masquerades as queueing latency.  Closes the
    // input topic when done.
    let engine_ready = Arc::new(std::sync::atomic::AtomicU32::new(0));
    let fleet_handle = {
        let broker2 = broker.clone();
        let in_topic2 = in_topic.clone();
        let clk2 = clk.clone();
        let tp = throughput.clone();
        let lat = latency.clone();
        let stop2 = stop.clone();
        let gen_cfg = GeneratorConfig::from_config(cfg);
        let workload = cfg.workload.clone();
        let duration = cfg.bench.duration_micros + cfg.bench.warmup_micros;
        let ready = engine_ready.clone();
        let parallelism = cfg.engine.parallelism;
        std::thread::Builder::new()
            .name("fleet-main".into())
            .spawn(move || {
                let wait_start = std::time::Instant::now();
                while ready.load(Ordering::SeqCst) < parallelism
                    && wait_start.elapsed().as_secs() < 60
                    && !stop2.load(Ordering::Relaxed)
                {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                let fleet = Fleet::new(gen_cfg, clk2, tp, lat);
                let report = fleet.run(&broker2, &in_topic2, duration, &stop2, |share| {
                    Pattern::from_config(&workload, share)
                });
                in_topic2.close();
                report
            })
            .expect("spawn fleet")
    };

    // Engine runs on this thread; exits when the input closes and drains.
    let engine_report = engine.run(
        &broker,
        "ingest",
        &out_topic,
        &stop,
        cfg.bench.duration_micros + cfg.bench.warmup_micros + 30_000_000,
        runtime_factory,
        Some(engine_ready),
    )?;
    let fleet_report = fleet_handle.join().map_err(|_| "fleet panicked")?;

    // Shut down sampler, broker, drainer (in that order).
    sampler_stop.store(true, Ordering::SeqCst);
    let (jmx, sysmon, cum_proc, cum_e2e) = sampler.join().map_err(|_| "sampler panicked")?;
    broker.shutdown();
    let drained = drainer.join().map_err(|_| "drainer panicked")?;

    // Whole-run latency summaries: cumulative copies for the drained
    // points, live recorder for the rest.
    let latency_summaries: Vec<(MeasurementPoint, HistogramSummary)> = MeasurementPoint::ALL
        .iter()
        .map(|&p| {
            let mut h = latency.merged(p);
            match p {
                MeasurementPoint::ProcOut => h.merge(&cum_proc),
                MeasurementPoint::EndToEnd => h.merge(&cum_e2e),
                _ => {}
            }
            (p, h.summary())
        })
        .collect();

    let (gc_count, gc_time) = jmx.aggregate_young();
    let summary = RunSummary {
        name: cfg.bench.name.clone(),
        pipeline: cfg.engine.pipeline_label(),
        framework: cfg.engine.framework.name(),
        parallelism: cfg.engine.parallelism,
        generated: fleet_report.events,
        processed: engine_report.events_in,
        emitted: drained,
        elapsed_micros: fleet_report.elapsed_micros,
        offered_rate: fleet_report.rate_events,
        processed_rate: engine_report.rate_events,
        offered_bytes_rate: fleet_report.rate_bytes,
        latency: latency_summaries,
        gc_young_count: gc_count,
        gc_young_time_micros: gc_time,
        energy_joules: sysmon.joules_total(),
        parse_failures: engine_report.parse_failures,
        batches: engine_report.batches,
        operators: engine_report.operators.clone(),
    };
    Ok((summary, store))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Framework, PipelineKind};
    use crate::postprocess::validate_results;

    fn quick_cfg() -> BenchConfig {
        let mut cfg = BenchConfig::default();
        cfg.bench.name = "coord-test".into();
        cfg.bench.duration_micros = 700_000;
        cfg.bench.warmup_micros = 0;
        cfg.workload.rate = 60_000;
        cfg.workload.sensors = 128;
        cfg.engine.parallelism = 2;
        cfg.engine.use_hlo = false;
        cfg.engine.batch_size = 256;
        cfg.metrics.sample_interval_micros = 100_000;
        cfg
    }

    #[test]
    fn wall_run_produces_consistent_summary() {
        let cfg = quick_cfg();
        let (summary, store) = run_wall(&cfg, None).unwrap();
        assert!(summary.generated > 10_000, "generated={}", summary.generated);
        assert_eq!(summary.processed, summary.generated, "engine must drain");
        assert_eq!(summary.emitted, summary.processed);
        assert_eq!(summary.parse_failures, 0);
        // Timeline series exist.
        assert!(store.get("throughput.driver_out.eps").is_some());
        assert!(store.get("jvm.engine-task-0.gc_young_count").is_some());
        assert!(store.get("energy.joules_total").is_some());
        // Latency recorded at the key points.
        let e2e = summary.latency_at(MeasurementPoint::EndToEnd).unwrap();
        assert_eq!(e2e.count, summary.processed);
        assert!(e2e.p50 > 0);
        // Results doc passes validation.
        let violations = validate_results(&summary.to_json());
        assert!(violations.is_empty(), "{violations:?}");
        // Per-operator stats survive into the results document.
        let names: Vec<&str> = summary.operators.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["cpu_transform", "emit_events"]);
        assert_eq!(summary.operators[0].1.events_in, summary.processed);
        let ops = summary.to_json();
        let ops = ops.get("operators").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(ops.len(), 2);
        assert_eq!(ops[0].get("op").and_then(|v| v.as_str()), Some("cpu_transform"));
    }

    #[test]
    fn spark_personality_has_higher_latency_than_flink() {
        let mut flink = quick_cfg();
        flink.engine.framework = Framework::Flink;
        let mut spark = quick_cfg();
        spark.engine.framework = Framework::Spark;
        spark.engine.microbatch_micros = 150_000;
        let (sf, _) = run_wall(&flink, None).unwrap();
        let (ss, _) = run_wall(&spark, None).unwrap();
        let lf = sf.latency_at(MeasurementPoint::EndToEnd).unwrap().p50;
        let ls = ss.latency_at(MeasurementPoint::EndToEnd).unwrap().p50;
        assert!(
            ls > lf,
            "micro-batching must cost latency: spark p50 {ls} <= flink p50 {lf}"
        );
    }

    #[test]
    fn mem_pipeline_summary_validates() {
        let mut cfg = quick_cfg();
        cfg.engine.pipeline = PipelineKind::MemIntensive;
        cfg.engine.window_micros = 300_000;
        cfg.engine.slide_micros = 100_000;
        let (summary, _) = run_wall(&cfg, None).unwrap();
        assert!(summary.emitted > 0, "window aggregates must flow");
        let violations = validate_results(&summary.to_json());
        assert!(violations.is_empty(), "{violations:?}");
    }
}
