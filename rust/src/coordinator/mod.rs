//! Benchmark coordinator: wires generator fleet → broker → engine → broker
//! together with the full monitoring stack, runs one experiment, and
//! produces the results document.
//!
//! * [`run_wall`] — real-thread, real-time execution on this machine.
//! * [`simrun::run_sim`] — analytic execution at cluster scale in virtual
//!   time (the 630-node Barnard runs of the paper).
//!
//! Both return the same [`RunSummary`] shape, so post-processing, the
//! workflow manager, the CLI and the benches treat them uniformly — and
//! [`crate::experiment::MaxCapacityDriver`] can wrap either entry point
//! in its stepped-load escalation loop.

pub mod simrun;

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use crate::broker::{Broker, BrokerConfig, Topic};
use crate::config::BenchConfig;
use crate::engine::{CheckpointCoordinator, CheckpointStore, Engine, RunHooks};
use crate::jvm::JmxSampler;
use crate::metrics::{LatencyRecorder, MeasurementPoint, MetricStore, ThroughputRecorder};
use crate::pipelines::StepFactory;
use crate::runtime::RuntimeFactory;
use crate::sysmon::{ActivityModel, NodeSpec, SysmonSampler};
use crate::util::clock::{self, ClockRef};
use crate::util::histogram::{Histogram, HistogramSummary};
use crate::util::json::Json;
use crate::wgen::{Fleet, FleetReport, GeneratorConfig, Pattern};

/// What a kill-and-restore run ([`run_recovery`]) measured, reported in
/// the results document as the `recovery` block.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RecoveryStats {
    /// Kill switch flip → every restarted task ready to consume, µs.
    pub recovery_time_micros: u64,
    /// Records the killed incarnation had ingested beyond the restore
    /// point — re-read and re-processed by the restarted incarnation.
    pub replayed_records: u64,
    /// Epoch of the checkpoint restored from (0 on a cold start).
    pub restored_epoch: u64,
    /// True when no valid checkpoint survived (or `fault.restore` was
    /// off) and the engine restarted from scratch.
    pub cold_start: bool,
    /// Corrupt or truncated checkpoint files the latest-scan skipped.
    pub corrupt_skipped: u64,
    /// Committed checkpoint files across both incarnations.
    pub checkpoints: u64,
    pub checkpoint_bytes: u64,
    /// Wall time spent assembling + writing committed checkpoints, µs.
    pub checkpoint_write_micros: u64,
}

/// Everything one experiment run produced.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub name: String,
    /// Pipeline label: the kind name (`cpu`) or a `chain[...]` label for
    /// explicit operator-chain specs.
    pub pipeline: String,
    pub framework: &'static str,
    pub parallelism: u32,
    pub generated: u64,
    pub processed: u64,
    pub emitted: u64,
    pub elapsed_micros: u64,
    /// Offered load achieved by the fleet, events/second.
    pub offered_rate: f64,
    /// Engine-processed events/second.
    pub processed_rate: f64,
    pub offered_bytes_rate: f64,
    pub latency: Vec<(MeasurementPoint, HistogramSummary)>,
    pub gc_young_count: u64,
    pub gc_young_time_micros: u64,
    pub energy_joules: f64,
    pub parse_failures: u64,
    pub batches: u64,
    /// Per-operator stats merged across engine tasks, in chain order
    /// (empty for sim runs — the analytic model has no per-op counters).
    pub operators: Vec<(String, crate::pipelines::StepStats)>,
    /// Kill-and-restore measurements; `None` for fault-free runs.
    pub recovery: Option<RecoveryStats>,
}

impl RunSummary {
    pub fn latency_at(&self, point: MeasurementPoint) -> Option<&HistogramSummary> {
        self.latency.iter().find(|(p, _)| *p == point).map(|(_, s)| s)
    }

    /// The results.json document (checked by `postprocess::validate`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set("pipeline", Json::Str(self.pipeline.to_string()));
        j.set("framework", Json::Str(self.framework.to_string()));
        j.set("parallelism", Json::Int(self.parallelism as i64));
        let mut events = Json::obj();
        events.set("generated", Json::Int(self.generated as i64));
        events.set("processed", Json::Int(self.processed as i64));
        events.set("emitted", Json::Int(self.emitted as i64));
        j.set("events", events);
        let mut tp = Json::obj();
        tp.set("offered", Json::Num(self.offered_rate));
        tp.set("processed", Json::Num(self.processed_rate));
        tp.set("offered_bytes", Json::Num(self.offered_bytes_rate));
        j.set("throughput", tp);
        let mut lat = Json::obj();
        for (point, s) in &self.latency {
            if s.count == 0 {
                continue;
            }
            let mut p = Json::obj();
            p.set("mean", Json::Num(s.mean));
            p.set("p50", Json::Int(s.p50 as i64));
            p.set("p95", Json::Int(s.p95 as i64));
            p.set("p99", Json::Int(s.p99 as i64));
            p.set("max", Json::Int(s.max as i64));
            p.set("count", Json::Int(s.count as i64));
            lat.set(point.name(), p);
        }
        j.set("latency_us", lat);
        let mut gc = Json::obj();
        gc.set("young_count", Json::Int(self.gc_young_count as i64));
        gc.set(
            "young_time_ms",
            Json::Num(self.gc_young_time_micros as f64 / 1e3),
        );
        j.set("gc", gc);
        let mut energy = Json::obj();
        energy.set("joules", Json::Num(self.energy_joules));
        j.set("energy", energy);
        j.set("elapsed_us", Json::Int(self.elapsed_micros as i64));
        j.set("parse_failures", Json::Int(self.parse_failures as i64));
        j.set("batches", Json::Int(self.batches as i64));
        if let Some(r) = &self.recovery {
            let mut rec = Json::obj();
            rec.set("recovery_time_us", Json::Int(r.recovery_time_micros as i64));
            rec.set("replayed_records", Json::Int(r.replayed_records as i64));
            rec.set("restored_epoch", Json::Int(r.restored_epoch as i64));
            rec.set("cold_start", Json::Bool(r.cold_start));
            rec.set("corrupt_skipped", Json::Int(r.corrupt_skipped as i64));
            rec.set("checkpoints", Json::Int(r.checkpoints as i64));
            rec.set("checkpoint_bytes", Json::Int(r.checkpoint_bytes as i64));
            rec.set("checkpoint_write_us", Json::Int(r.checkpoint_write_micros as i64));
            j.set("recovery", rec);
        }
        // Per-operator breakdown, chain order preserved (array, not map).
        let ops: Vec<Json> = self
            .operators
            .iter()
            .map(|(name, s)| {
                let mut o = s.to_json();
                o.set("op", Json::Str(name.clone()));
                o
            })
            .collect();
        j.set("operators", Json::Arr(ops));
        j
    }
}

/// The shared wall-mode scaffold behind [`run_wall`] and
/// [`run_recovery`]: broker + topics, egestion drainer, engine (heaps
/// JMX-registered), interval sampler, and the generator fleet.  The
/// fleet waits for `engine_ready` before offering load and closes the
/// input topic when its run span elapses — which is what eventually
/// makes the engine phase(s) drain and return.
struct WallHarness {
    clk: ClockRef,
    store: Arc<MetricStore>,
    latency: Arc<LatencyRecorder>,
    broker: Arc<Broker>,
    in_topic: Arc<Topic>,
    out_topic: Arc<Topic>,
    engine: Engine,
    stop: Arc<AtomicBool>,
    engine_ready: Arc<AtomicU32>,
    drainer: std::thread::JoinHandle<u64>,
    sampler_stop: Arc<AtomicBool>,
    sampler: std::thread::JoinHandle<(JmxSampler, SysmonSampler, Histogram, Histogram)>,
    fleet: std::thread::JoinHandle<FleetReport>,
}

/// Everything [`WallHarness::finish`] collects after the engine phase(s).
struct WallTeardown {
    fleet: FleetReport,
    drained: u64,
    latency: Vec<(MeasurementPoint, HistogramSummary)>,
    gc_young_count: u64,
    gc_young_time_micros: u64,
    energy_joules: f64,
}

impl WallHarness {
    /// Engine deadline: the configured run span plus generous slack for
    /// pipeline compilation and final drain.
    fn engine_deadline(cfg: &BenchConfig) -> u64 {
        cfg.bench.duration_micros + cfg.bench.warmup_micros + 30_000_000
    }

    fn start(cfg: &BenchConfig) -> WallHarness {
        let clk: ClockRef = clock::wall();
        let store = Arc::new(MetricStore::new());
        let throughput = Arc::new(ThroughputRecorder::new());
        let latency = Arc::new(LatencyRecorder::new());

        let broker = Broker::new(BrokerConfig::from_section(&cfg.broker), clk.clone());
        let in_topic = broker.create_topic("ingest");
        let out_topic = broker.create_topic("egest");

        // Egestion drainer: the downstream consumer of processed results.
        let drain_group = broker.subscribe("egest", "downstream", 1);
        let drainer = {
            let g = drain_group;
            std::thread::Builder::new()
                .name("egest-drain".into())
                .spawn(move || {
                    let mut n = 0u64;
                    loop {
                        match g.poll(0, 4096) {
                            Ok(Some(b)) => {
                                n += b.record_count() as u64;
                                g.commit(b.partition, b.next_offset);
                            }
                            Ok(None) => std::thread::sleep(std::time::Duration::from_micros(500)),
                            Err(_) => return n,
                        }
                    }
                })
                .expect("spawn drainer")
        };

        // Engine first: its heaps register with JMX before sampling starts.
        let engine = Engine::new(cfg, clk.clone(), throughput.clone(), latency.clone());
        let mut jmx = JmxSampler::new(clk.clone(), store.clone());
        for (i, h) in engine.heaps.iter().enumerate() {
            jmx.register(&format!("engine-task-{i}"), h.clone());
        }
        let mut sysmon = SysmonSampler::new(
            clk.clone(),
            store.clone(),
            throughput.clone(),
            NodeSpec::default(),
            ActivityModel::default(),
        );

        let stop = Arc::new(AtomicBool::new(false));
        let sampler_stop = Arc::new(AtomicBool::new(false));

        // Interval sampler: throughput rates + per-interval latency timeline
        // (the Fig. 8 series) + JMX + sysmon.  ProcOut/EndToEnd histograms are
        // drained per interval for the timeline and merged into cumulative
        // copies for the whole-run summary.
        let sampler = {
            let clk = clk.clone();
            let store = store.clone();
            let tp = throughput.clone();
            let lat = latency.clone();
            let stop = sampler_stop.clone();
            let interval = cfg.metrics.sample_interval_micros.max(10_000);
            std::thread::Builder::new()
                .name("metrics-sampler".into())
                .spawn(move || {
                    let mut prev = tp.snapshot();
                    let mut prev_t = clk.now_micros();
                    let mut cum_proc = Histogram::new();
                    let mut cum_e2e = Histogram::new();
                    loop {
                        let stopping = stop.load(Ordering::Relaxed);
                        if !stopping {
                            clk.sleep_micros(interval);
                        }
                        let now = clk.now_micros();
                        let snap = tp.snapshot();
                        let dt = now.saturating_sub(prev_t).max(1);
                        for p in MeasurementPoint::ALL {
                            store.append(
                                &format!("throughput.{}.eps", p.name()),
                                now,
                                snap.rate_events(&prev, p, dt),
                            );
                            store.append(
                                &format!("throughput.{}.bps", p.name()),
                                now,
                                snap.rate_bytes(&prev, p, dt),
                            );
                        }
                        for (p, cum) in [
                            (MeasurementPoint::ProcOut, &mut cum_proc),
                            (MeasurementPoint::EndToEnd, &mut cum_e2e),
                        ] {
                            let h = lat.drain(p);
                            if !h.is_empty() {
                                store.append(&format!("latency.{}.p50_us", p.name()), now, h.p50() as f64);
                                store.append(&format!("latency.{}.p99_us", p.name()), now, h.p99() as f64);
                                store.append(&format!("latency.{}.mean_us", p.name()), now, h.mean());
                                cum.merge(&h);
                            }
                        }
                        jmx.sample();
                        sysmon.sample();
                        prev = snap;
                        prev_t = now;
                        if stopping {
                            return (jmx, sysmon, cum_proc, cum_e2e);
                        }
                    }
                })
                .expect("spawn sampler")
        };

        // Fleet in the background; it waits for every engine task to finish
        // building its pipeline step (PJRT compile) before offering load, so
        // compile time never masquerades as queueing latency.  Closes the
        // input topic when done.
        let engine_ready = Arc::new(AtomicU32::new(0));
        let fleet = {
            let broker2 = broker.clone();
            let in_topic2 = in_topic.clone();
            let clk2 = clk.clone();
            let tp = throughput.clone();
            let lat = latency.clone();
            let stop2 = stop.clone();
            let gen_cfg = GeneratorConfig::from_config(cfg);
            let workload = cfg.workload.clone();
            let duration = cfg.bench.duration_micros + cfg.bench.warmup_micros;
            let ready = engine_ready.clone();
            let parallelism = cfg.engine.parallelism;
            std::thread::Builder::new()
                .name("fleet-main".into())
                .spawn(move || {
                    let wait_start = std::time::Instant::now();
                    while ready.load(Ordering::SeqCst) < parallelism
                        && wait_start.elapsed().as_secs() < 60
                        && !stop2.load(Ordering::Relaxed)
                    {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    let fleet = Fleet::new(gen_cfg, clk2, tp, lat);
                    let report = fleet.run(&broker2, &in_topic2, duration, &stop2, |share| {
                        Pattern::from_config(&workload, share)
                    });
                    in_topic2.close();
                    report
                })
                .expect("spawn fleet")
        };

        WallHarness {
            clk,
            store,
            latency,
            broker,
            in_topic,
            out_topic,
            engine,
            stop,
            engine_ready,
            drainer,
            sampler_stop,
            sampler,
            fleet,
        }
    }

    /// Join the fleet, stop the sampler, shut the broker down, join the
    /// drainer (in that order), and fold the cumulative latency copies
    /// back into the whole-run summaries.
    fn finish(self) -> Result<WallTeardown, String> {
        let fleet = self.fleet.join().map_err(|_| "fleet panicked")?;
        self.sampler_stop.store(true, Ordering::SeqCst);
        let (jmx, sysmon, cum_proc, cum_e2e) =
            self.sampler.join().map_err(|_| "sampler panicked")?;
        self.broker.shutdown();
        let drained = self.drainer.join().map_err(|_| "drainer panicked")?;

        // Whole-run latency summaries: cumulative copies for the drained
        // points, live recorder for the rest.
        let latency: Vec<(MeasurementPoint, HistogramSummary)> = MeasurementPoint::ALL
            .iter()
            .map(|&p| {
                let mut h = self.latency.merged(p);
                match p {
                    MeasurementPoint::ProcOut => h.merge(&cum_proc),
                    MeasurementPoint::EndToEnd => h.merge(&cum_e2e),
                    _ => {}
                }
                (p, h.summary())
            })
            .collect();

        let (gc_young_count, gc_young_time_micros) = jmx.aggregate_young();
        Ok(WallTeardown {
            fleet,
            drained,
            latency,
            gc_young_count,
            gc_young_time_micros,
            energy_joules: sysmon.joules_total(),
        })
    }
}

/// Run one experiment in wall mode. Returns the summary and the metric
/// store (the timeline series behind the Fig. 8-style plots).
pub fn run_wall(
    cfg: &BenchConfig,
    runtime_factory: Option<RuntimeFactory>,
) -> Result<(RunSummary, Arc<MetricStore>), String> {
    let h = WallHarness::start(cfg);

    // Engine runs on this thread; exits when the input closes and drains.
    let engine_report = h.engine.run(
        &h.broker,
        "ingest",
        &h.out_topic,
        &h.stop,
        WallHarness::engine_deadline(cfg),
        runtime_factory,
        Some(h.engine_ready.clone()),
    )?;

    let store = h.store.clone();
    let t = h.finish()?;
    let summary = RunSummary {
        name: cfg.bench.name.clone(),
        pipeline: cfg.engine.pipeline_label(),
        framework: cfg.engine.framework.name(),
        parallelism: cfg.engine.parallelism,
        generated: t.fleet.events,
        processed: engine_report.events_in,
        emitted: t.drained,
        elapsed_micros: t.fleet.elapsed_micros,
        offered_rate: t.fleet.rate_events,
        processed_rate: engine_report.rate_events,
        offered_bytes_rate: t.fleet.rate_bytes,
        latency: t.latency,
        gc_young_count: t.gc_young_count,
        gc_young_time_micros: t.gc_young_time_micros,
        energy_joules: t.energy_joules,
        parse_failures: engine_report.parse_failures,
        batches: engine_report.batches,
        operators: engine_report.operators.clone(),
        recovery: None,
    };
    Ok((summary, store))
}

/// Run one experiment in wall mode under the configured fault plan
/// (`fault.kill_after`): checkpointing is armed, the engine incarnation
/// is killed mid-run, and a second incarnation restarts from the newest
/// valid checkpoint — or cold when none survives or `fault.restore` is
/// off.  The generator fleet keeps offering load across the outage, so
/// the backlog that accumulates while the engine is down is replayed and
/// drained by the restarted incarnation.
///
/// The summary merges both incarnations: `processed` counts distinct
/// records (replays subtracted), and the `recovery` block reports
/// recovery time (kill → every restarted task ready), replay volume and
/// checkpoint cost.  `emitted` stays the raw egestion count, which can
/// exceed a fault-free run's — records processed between the last
/// durable snapshot and the kill are emitted twice (at-least-once
/// egestion; exactly-once applies to state, not to the output topic).
pub fn run_recovery(
    cfg: &BenchConfig,
    runtime_factory: Option<RuntimeFactory>,
) -> Result<(RunSummary, Arc<MetricStore>), String> {
    if !cfg.fault.enabled() {
        return run_wall(cfg, runtime_factory);
    }
    let h = WallHarness::start(cfg);
    let clk = h.clk.clone();
    let parallelism = cfg.engine.parallelism;
    let factory = Arc::new(StepFactory::new(cfg, runtime_factory));
    let deadline = WallHarness::engine_deadline(cfg);
    let ckpt_dir = cfg.checkpoint_dir();
    let retain = cfg.checkpoint.retain;

    // Phase 1: checkpointing armed, kill watchdog ticking.  The watchdog
    // arms itself only once every task is ready to consume (so a slow
    // pipeline compile cannot eat the fault window), then flips the crash
    // switch `fault.kill_after` later and records when it fired.
    let epoch_origin = clk.now_micros();
    let coord1 = cfg.checkpoint.enabled().then(|| {
        Arc::new(CheckpointCoordinator::new(
            CheckpointStore::new(ckpt_dir.as_str(), retain),
            parallelism as usize,
            cfg.checkpoint.interval_micros,
            epoch_origin,
        ))
    });
    let kill = Arc::new(AtomicBool::new(false));
    let killed_at = Arc::new(AtomicU64::new(0));
    let phase1_done = Arc::new(AtomicBool::new(false));
    let watchdog = {
        let clk = clk.clone();
        let kill = kill.clone();
        let killed_at = killed_at.clone();
        let done = phase1_done.clone();
        let ready = h.engine_ready.clone();
        let kill_after = cfg.fault.kill_after_micros;
        std::thread::Builder::new()
            .name("fault-watchdog".into())
            .spawn(move || {
                let mut armed_at = None;
                loop {
                    if done.load(Ordering::SeqCst) {
                        return; // the run ended before the fault fired
                    }
                    let now = clk.now_micros();
                    if armed_at.is_none() && ready.load(Ordering::SeqCst) >= parallelism {
                        armed_at = Some(now);
                    }
                    if armed_at.is_some_and(|t0| now >= t0 + kill_after) {
                        killed_at.store(now, Ordering::SeqCst);
                        kill.store(true, Ordering::SeqCst);
                        return;
                    }
                    std::thread::sleep(std::time::Duration::from_micros(500));
                }
            })
            .expect("spawn fault watchdog")
    };
    let r1 = h.engine.run_with_hooks(
        &h.broker,
        "ingest",
        &h.out_topic,
        &h.stop,
        deadline,
        factory.clone(),
        Some(h.engine_ready.clone()),
        RunHooks {
            checkpoint: coord1.clone(),
            kill: Some(kill.clone()),
            restore_from: None,
        },
    )?;
    phase1_done.store(true, Ordering::SeqCst);
    watchdog.join().map_err(|_| "fault watchdog panicked")?;

    // Between incarnations: find the newest valid checkpoint.  Corrupt
    // or truncated files are skipped (counted), and a missing checkpoint
    // degrades to a cold start — the fresh consumer group then replays
    // from the earliest retained offsets.
    let scan = CheckpointStore::new(ckpt_dir.as_str(), retain).latest();
    let corrupt_skipped = scan.skipped.len() as u64;
    let restored = if cfg.fault.restore { scan.checkpoint } else { None };
    let cold_start = restored.is_none();
    let restored_epoch = restored.as_ref().map_or(0, |c| c.epoch);
    // Replay volume: everything phase 1 ingested beyond the restore
    // point gets re-read by the restarted incarnation.  On a cold start
    // the restore point is the pruned prefix of the log (offsets below
    // the low watermark are gone and cannot be replayed).
    let durable_in = match &restored {
        Some(c) => c.events_in(),
        None => (0..h.in_topic.partition_count())
            .map(|p| h.in_topic.partition(p).low_watermark())
            .sum(),
    };
    let replayed = r1.events_in.saturating_sub(durable_in);

    // Phase 2: restart with restore hooks.  The coordinator keeps phase
    // 1's epoch origin so the restarted incarnation's checkpoint files
    // continue the epoch numbering — never colliding with (or sorting
    // older than) the ones already on disk.
    let coord2 = coord1.as_ref().map(|_| {
        Arc::new(CheckpointCoordinator::new(
            CheckpointStore::new(ckpt_dir.as_str(), retain),
            parallelism as usize,
            cfg.checkpoint.interval_micros,
            epoch_origin,
        ))
    });
    let ready2 = Arc::new(AtomicU32::new(0));
    let ready2_at = Arc::new(AtomicU64::new(0));
    let monitor = {
        let clk = clk.clone();
        let ready2 = ready2.clone();
        let ready2_at = ready2_at.clone();
        let stop = h.stop.clone();
        std::thread::Builder::new()
            .name("recovery-monitor".into())
            .spawn(move || {
                let t0 = std::time::Instant::now();
                while ready2.load(Ordering::SeqCst) < parallelism
                    && t0.elapsed().as_secs() < 60
                    && !stop.load(Ordering::Relaxed)
                {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                ready2_at.store(clk.now_micros(), Ordering::SeqCst);
            })
            .expect("spawn recovery monitor")
    };
    let r2 = h.engine.run_with_hooks(
        &h.broker,
        "ingest",
        &h.out_topic,
        &h.stop,
        deadline,
        factory,
        Some(ready2.clone()),
        RunHooks {
            checkpoint: coord2.clone(),
            kill: None,
            restore_from: restored.map(Arc::new),
        },
    )?;
    monitor.join().map_err(|_| "recovery monitor panicked")?;
    let killed_at = killed_at.load(Ordering::SeqCst);
    let recovery_time_micros = if killed_at == 0 {
        0 // the run ended before the fault fired; nothing was recovered
    } else {
        ready2_at.load(Ordering::SeqCst).saturating_sub(killed_at)
    };

    let cs1 = coord1.as_ref().map(|c| c.stats()).unwrap_or_default();
    let cs2 = coord2.as_ref().map(|c| c.stats()).unwrap_or_default();
    let recovery = RecoveryStats {
        recovery_time_micros,
        replayed_records: replayed,
        restored_epoch,
        cold_start,
        corrupt_skipped,
        checkpoints: cs1.committed + cs2.committed,
        checkpoint_bytes: cs1.bytes + cs2.bytes,
        checkpoint_write_micros: cs1.write_micros + cs2.write_micros,
    };

    let store = h.store.clone();
    let t = h.finish()?;
    // Distinct records processed: both incarnations' intake minus the
    // replayed overlap.  Killed tasks lose their in-memory operator
    // counters, so the per-operator breakdown is the restarted
    // incarnation's (complete from the restore point onward).
    let processed = (r1.events_in + r2.events_in).saturating_sub(replayed);
    let elapsed = t.fleet.elapsed_micros.max(1);
    let summary = RunSummary {
        name: cfg.bench.name.clone(),
        pipeline: cfg.engine.pipeline_label(),
        framework: cfg.engine.framework.name(),
        parallelism: cfg.engine.parallelism,
        generated: t.fleet.events,
        processed,
        emitted: t.drained,
        elapsed_micros: t.fleet.elapsed_micros,
        offered_rate: t.fleet.rate_events,
        processed_rate: processed as f64 * 1e6 / elapsed as f64,
        offered_bytes_rate: t.fleet.rate_bytes,
        latency: t.latency,
        gc_young_count: t.gc_young_count,
        gc_young_time_micros: t.gc_young_time_micros,
        energy_joules: t.energy_joules,
        parse_failures: r1.parse_failures + r2.parse_failures,
        batches: r1.batches + r2.batches,
        operators: r2.operators.clone(),
        recovery: Some(recovery),
    };
    Ok((summary, store))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Framework, PipelineKind};
    use crate::postprocess::validate_results;

    fn quick_cfg() -> BenchConfig {
        let mut cfg = BenchConfig::default();
        cfg.bench.name = "coord-test".into();
        cfg.bench.duration_micros = 700_000;
        cfg.bench.warmup_micros = 0;
        cfg.workload.rate = 60_000;
        cfg.workload.sensors = 128;
        cfg.engine.parallelism = 2;
        cfg.engine.use_hlo = false;
        cfg.engine.batch_size = 256;
        cfg.metrics.sample_interval_micros = 100_000;
        cfg
    }

    #[test]
    fn wall_run_produces_consistent_summary() {
        let cfg = quick_cfg();
        let (summary, store) = run_wall(&cfg, None).unwrap();
        assert!(summary.generated > 10_000, "generated={}", summary.generated);
        assert_eq!(summary.processed, summary.generated, "engine must drain");
        assert_eq!(summary.emitted, summary.processed);
        assert_eq!(summary.parse_failures, 0);
        // Timeline series exist.
        assert!(store.get("throughput.driver_out.eps").is_some());
        assert!(store.get("jvm.engine-task-0.gc_young_count").is_some());
        assert!(store.get("energy.joules_total").is_some());
        // Latency recorded at the key points.
        let e2e = summary.latency_at(MeasurementPoint::EndToEnd).unwrap();
        assert_eq!(e2e.count, summary.processed);
        assert!(e2e.p50 > 0);
        // Results doc passes validation.
        let violations = validate_results(&summary.to_json());
        assert!(violations.is_empty(), "{violations:?}");
        // Per-operator stats survive into the results document.
        let names: Vec<&str> = summary.operators.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["cpu_transform", "emit_events"]);
        assert_eq!(summary.operators[0].1.events_in, summary.processed);
        let ops = summary.to_json();
        let ops = ops.get("operators").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(ops.len(), 2);
        assert_eq!(ops[0].get("op").and_then(|v| v.as_str()), Some("cpu_transform"));
    }

    #[test]
    fn recovery_run_replays_and_conserves_distinct_records() {
        let mut cfg = quick_cfg();
        cfg.bench.name = "coord-recovery".into();
        cfg.bench.duration_micros = 1_500_000;
        cfg.checkpoint.interval_micros = 150_000;
        cfg.checkpoint.dir = std::env::temp_dir()
            .join(format!("sprobench-coord-recovery-{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        cfg.fault.kill_after_micros = 500_000;
        cfg.fault.kill_task = 1;
        std::fs::remove_dir_all(&cfg.checkpoint.dir).ok();
        let (summary, _) = run_recovery(&cfg, None).unwrap();
        std::fs::remove_dir_all(&cfg.checkpoint.dir).ok();
        let rec = summary.recovery.expect("fault run must report recovery");
        assert!(rec.recovery_time_micros > 0, "kill→ready must take time");
        assert!(!rec.cold_start, "checkpoints were enabled: {rec:?}");
        assert!(rec.checkpoints > 0, "no checkpoint committed before kill");
        assert!(rec.checkpoint_bytes > 0);
        assert!(rec.replayed_records > 0, "kill mid-epoch must force replay");
        assert_eq!(rec.corrupt_skipped, 0);
        // Exactly-once accounting: replays are subtracted, so distinct
        // processed records equal the offered load.
        assert_eq!(summary.processed, summary.generated, "{rec:?}");
        // At-least-once egestion: nothing the engine emitted is lost.
        assert!(summary.emitted >= summary.processed);
        let j = summary.to_json();
        let rj = j.get("recovery").expect("recovery block in results.json");
        assert!(rj.get("recovery_time_us").and_then(|v| v.as_i64()).unwrap() > 0);
        assert_eq!(rj.get("cold_start").and_then(|v| v.as_bool()), Some(false));
        let violations = validate_results(&j);
        assert!(violations.is_empty(), "{violations:?}");
    }

    #[test]
    fn recovery_without_fault_plan_is_a_plain_wall_run() {
        let mut cfg = quick_cfg();
        cfg.bench.duration_micros = 400_000;
        let (summary, _) = run_recovery(&cfg, None).unwrap();
        assert!(summary.recovery.is_none(), "no fault → no recovery block");
    }

    #[test]
    fn spark_personality_has_higher_latency_than_flink() {
        let mut flink = quick_cfg();
        flink.engine.framework = Framework::Flink;
        let mut spark = quick_cfg();
        spark.engine.framework = Framework::Spark;
        spark.engine.microbatch_micros = 150_000;
        let (sf, _) = run_wall(&flink, None).unwrap();
        let (ss, _) = run_wall(&spark, None).unwrap();
        let lf = sf.latency_at(MeasurementPoint::EndToEnd).unwrap().p50;
        let ls = ss.latency_at(MeasurementPoint::EndToEnd).unwrap().p50;
        assert!(
            ls > lf,
            "micro-batching must cost latency: spark p50 {ls} <= flink p50 {lf}"
        );
    }

    #[test]
    fn mem_pipeline_summary_validates() {
        let mut cfg = quick_cfg();
        cfg.engine.pipeline = PipelineKind::MemIntensive;
        cfg.engine.window_micros = 300_000;
        cfg.engine.slide_micros = 100_000;
        let (summary, _) = run_wall(&cfg, None).unwrap();
        assert!(summary.emitted > 0, "window aggregates must flow");
        let violations = validate_results(&summary.to_json());
        assert!(violations.is_empty(), "{violations:?}");
    }
}
