//! Configuration system: one master file controls every component.
//!
//! * [`yaml`] — indentation-based YAML-subset parser (offline substrate).
//! * [`schema`] — typed [`BenchConfig`] with defaults + validation.
//! * [`overlay`]/[`expand_experiments`] — the paper's multi-experiment
//!   feature: the `experiments:` list applies dotted-key overrides to the
//!   base document, yielding one resolved config per experiment from a
//!   single file (paper Sec. 3.1: "multiple experiments ... from a single
//!   configuration file").

pub mod schema;
pub mod yaml;

pub use schema::{
    parse_pipeline_spec, pipeline_grammar, BenchConfig, CheckpointSection, ClusterSection, CmpOp,
    ConfigError, DisorderSection, ExchangeMode, ExecMode, FaultKind, FaultSection, FaultSpec,
    Framework, OpSpec, Pattern, PipelineKind, PipelineSpec, StageSpec, TransportMode,
};

use crate::util::json::Json;

/// Apply a dotted-key override (`"engine.parallelism" = 8`) onto a tree.
pub fn overlay(base: &mut Json, dotted_key: &str, value: Json) {
    let parts: Vec<&str> = dotted_key.split('.').collect();
    let mut cur = base;
    for (i, part) in parts.iter().enumerate() {
        if i + 1 == parts.len() {
            if let Json::Obj(m) = cur {
                m.insert(part.to_string(), value);
            }
            return;
        }
        if let Json::Obj(m) = cur {
            cur = m.entry(part.to_string()).or_insert_with(Json::obj);
            if !matches!(cur, Json::Obj(_)) {
                *cur = Json::obj();
            }
        } else {
            return;
        }
    }
}

/// One named, fully-resolved experiment.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub name: String,
    pub config: BenchConfig,
    /// The resolved document (for traceability logging in the run dir).
    pub resolved: Json,
}

/// Expand the `experiments:` list of a master document into resolved
/// configs.  Without an `experiments:` list the document itself is the
/// single experiment.
pub fn expand_experiments(doc: &Json) -> Result<Vec<Experiment>, ConfigError> {
    let base_name = doc
        .path(&["benchmark", "name"])
        .and_then(|v| v.as_str())
        .unwrap_or("bench")
        .to_string();

    let Some(list) = doc.get("experiments").and_then(|e| e.as_arr()) else {
        let config = BenchConfig::from_json(doc)?;
        return Ok(vec![Experiment {
            name: base_name,
            config,
            resolved: doc.clone(),
        }]);
    };

    let mut out = Vec::with_capacity(list.len());
    for (i, exp) in list.iter().enumerate() {
        let mut resolved = doc.clone();
        if let Json::Obj(m) = &mut resolved {
            m.remove("experiments");
        }
        let mut name = format!("{base_name}-{i}");
        if let Json::Obj(pairs) = exp {
            for (k, v) in pairs {
                if k == "name" {
                    if let Some(n) = v.as_str() {
                        name = n.to_string();
                    }
                    continue;
                }
                overlay(&mut resolved, k, v.clone());
            }
        } else {
            return Err(ConfigError(format!(
                "experiments[{i}]: expected a mapping of overrides"
            )));
        }
        overlay(&mut resolved, "benchmark.name", Json::Str(name.clone()));
        let config = BenchConfig::from_json(&resolved)?;
        out.push(Experiment {
            name,
            config,
            resolved,
        });
    }
    Ok(out)
}

/// Load a config file (YAML subset) and expand its experiments.
pub fn load_file(path: &std::path::Path) -> Result<Vec<Experiment>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    let doc = yaml::parse(&text).map_err(|e| e.to_string())?;
    expand_experiments(&doc).map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config as PtConfig};

    #[test]
    fn overlay_nested_creates_path() {
        let mut j = Json::obj();
        overlay(&mut j, "a.b.c", Json::Int(5));
        assert_eq!(j.path(&["a", "b", "c"]).unwrap().as_i64(), Some(5));
    }

    #[test]
    fn overlay_replaces_existing() {
        let mut j = yaml::parse("engine:\n  parallelism: 4\n").unwrap();
        overlay(&mut j, "engine.parallelism", Json::Int(16));
        assert_eq!(j.path(&["engine", "parallelism"]).unwrap().as_i64(), Some(16));
    }

    #[test]
    fn single_experiment_without_list() {
        let doc = yaml::parse("benchmark:\n  name: solo\n").unwrap();
        let exps = expand_experiments(&doc).unwrap();
        assert_eq!(exps.len(), 1);
        assert_eq!(exps[0].name, "solo");
    }

    #[test]
    fn matrix_expansion_applies_overrides() {
        let doc = yaml::parse(
            "
benchmark:
  name: sweep
engine:
  parallelism: 1
experiments:
  - name: p2
    engine.parallelism: 2
  - name: p8
    engine.parallelism: 8
    workload.rate: 1M
",
        )
        .unwrap();
        let exps = expand_experiments(&doc).unwrap();
        assert_eq!(exps.len(), 2);
        assert_eq!(exps[0].name, "p2");
        assert_eq!(exps[0].config.engine.parallelism, 2);
        assert_eq!(exps[1].config.engine.parallelism, 8);
        assert_eq!(exps[1].config.workload.rate, 1_000_000);
        // Base doc untouched between expansions.
        assert_eq!(exps[0].config.workload.rate, 100_000);
    }

    #[test]
    fn invalid_override_is_reported() {
        let doc = yaml::parse("experiments:\n  - name: bad\n    workload.event_bytes: 5\n").unwrap();
        assert!(expand_experiments(&doc).is_err());
    }

    #[test]
    fn prop_overlay_then_read_roundtrips() {
        check(PtConfig::default().cases(100), "overlay-roundtrip", |g| {
            let depth = g.usize(1..4);
            let segs: Vec<String> = (0..depth)
                .map(|i| format!("k{}_{}", i, g.u64(0..5)))
                .collect();
            let key = segs.join(".");
            let val = g.i64(-1000..1000);
            let mut doc = Json::obj();
            overlay(&mut doc, &key, Json::Int(val));
            let path: Vec<&str> = segs.iter().map(|s| s.as_str()).collect();
            match doc.path(&path).and_then(|v| v.as_i64()) {
                Some(got) if got == val => Ok(()),
                other => Err(format!("key {key}: wrote {val}, read {other:?}")),
            }
        });
    }
}
