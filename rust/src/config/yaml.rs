//! Indentation-based YAML-subset parser producing [`Json`] values.
//!
//! SProBench's single master configuration file (paper Sec. 3: "A single
//! configuration file serves as a master control point") is YAML; serde_yaml
//! is not vendored, so this parser supports the subset the suite needs:
//!
//! * nested mappings by indentation (spaces only),
//! * block lists (`- item`, including list-of-mapping entries),
//! * inline scalars: ints, floats, bools, null, quoted + bare strings,
//! * inline lists `[a, b, c]`,
//! * comments (`# ...`) and blank lines,
//! * dotted keys are kept verbatim (the overlay layer interprets them).

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub struct YamlError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for YamlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "yaml error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for YamlError {}

struct Line {
    number: usize,
    indent: usize,
    text: String,
}

/// Parse a YAML-subset document into a [`Json`] tree.
pub fn parse(input: &str) -> Result<Json, YamlError> {
    let lines: Vec<Line> = input
        .lines()
        .enumerate()
        .filter_map(|(i, raw)| {
            let without_comment = strip_comment(raw);
            let trimmed = without_comment.trim_end();
            if trimmed.trim().is_empty() {
                return None;
            }
            let indent = trimmed.len() - trimmed.trim_start().len();
            Some(Line {
                number: i + 1,
                indent,
                text: trimmed.trim_start().to_string(),
            })
        })
        .collect();
    if lines.is_empty() {
        return Ok(Json::obj());
    }
    let mut pos = 0;
    let v = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos != lines.len() {
        return Err(YamlError {
            line: lines[pos].number,
            message: "unexpected dedent/content".into(),
        });
    }
    Ok(v)
}

fn strip_comment(raw: &str) -> &str {
    // A '#' starts a comment unless inside quotes.
    let bytes = raw.as_bytes();
    let mut in_s = false;
    let mut in_d = false;
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'\'' if !in_d => in_s = !in_s,
            b'"' if !in_s => in_d = !in_d,
            b'#' if !in_s && !in_d => {
                // Require '#' at start or after whitespace (YAML rule).
                if i == 0 || bytes[i - 1] == b' ' || bytes[i - 1] == b'\t' {
                    return &raw[..i];
                }
            }
            _ => {}
        }
    }
    raw
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    if lines[*pos].text.starts_with("- ") || lines[*pos].text == "-" {
        parse_list(lines, pos, indent)
    } else {
        parse_map(lines, pos, indent)
    }
}

fn parse_map(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let mut map = BTreeMap::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError {
                line: line.number,
                message: "unexpected indent".into(),
            });
        }
        if line.text.starts_with("- ") {
            break; // a list at this level belongs to the parent key
        }
        let (key, rest) = split_key(&line.text).ok_or_else(|| YamlError {
            line: line.number,
            message: "expected 'key: value'".into(),
        })?;
        *pos += 1;
        let value = if rest.is_empty() {
            // Nested block (map or list) or empty value.
            if *pos < lines.len() && lines[*pos].indent > indent {
                parse_block(lines, pos, lines[*pos].indent)?
            } else if *pos < lines.len()
                && lines[*pos].indent == indent
                && lines[*pos].text.starts_with("- ")
            {
                parse_list(lines, pos, indent)?
            } else {
                Json::Null
            }
        } else {
            scalar(rest)
        };
        map.insert(key.to_string(), value);
    }
    Ok(Json::Obj(map))
}

fn parse_list(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Json, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent != indent || !(line.text.starts_with("- ") || line.text == "-") {
            if line.indent >= indent && !line.text.starts_with("- ") {
                break;
            }
            if line.indent < indent {
                break;
            }
            return Err(YamlError {
                line: line.number,
                message: "malformed list item".into(),
            });
        }
        let inner = line.text.strip_prefix('-').unwrap().trim_start().to_string();
        let number = line.number;
        *pos += 1;
        if inner.is_empty() {
            // "- " alone: nested block as the item.
            if *pos < lines.len() && lines[*pos].indent > indent {
                items.push(parse_block(lines, pos, lines[*pos].indent)?);
            } else {
                items.push(Json::Null);
            }
        } else if let Some((k, rest)) = split_key(&inner) {
            // List item that is a mapping: first pair inline, continuation
            // lines are more deeply indented.
            let mut map = BTreeMap::new();
            let first_val = if rest.is_empty() {
                if *pos < lines.len() && lines[*pos].indent > indent + 2 {
                    parse_block(lines, pos, lines[*pos].indent)?
                } else {
                    Json::Null
                }
            } else {
                scalar(rest)
            };
            map.insert(k.to_string(), first_val);
            // Continuation pairs aligned under the first key (indent + 2).
            while *pos < lines.len() && lines[*pos].indent > indent {
                let cont_indent = lines[*pos].indent;
                match parse_map(lines, pos, cont_indent)? {
                    Json::Obj(m) => {
                        for (k, v) in m {
                            map.insert(k, v);
                        }
                    }
                    _ => {
                        return Err(YamlError {
                            line: number,
                            message: "bad mapping continuation in list".into(),
                        })
                    }
                }
            }
            items.push(Json::Obj(map));
        } else {
            items.push(scalar(&inner));
        }
    }
    Ok(Json::Arr(items))
}

fn split_key(text: &str) -> Option<(&str, &str)> {
    // Key ends at the first ':' that is followed by space or EOL and is not
    // inside quotes.
    let bytes = text.as_bytes();
    let mut in_s = false;
    let mut in_d = false;
    for i in 0..bytes.len() {
        match bytes[i] {
            b'\'' if !in_d => in_s = !in_s,
            b'"' if !in_s => in_d = !in_d,
            b':' if !in_s && !in_d => {
                if i + 1 == bytes.len() || bytes[i + 1] == b' ' {
                    let key = text[..i].trim();
                    let rest = text[i + 1..].trim();
                    if key.is_empty() {
                        return None;
                    }
                    return Some((key, rest));
                }
            }
            _ => {}
        }
    }
    None
}

/// Parse an inline scalar or inline list.
fn scalar(text: &str) -> Json {
    let t = text.trim();
    if t.starts_with('[') && t.ends_with(']') {
        let inner = &t[1..t.len() - 1];
        if inner.trim().is_empty() {
            return Json::Arr(vec![]);
        }
        return Json::Arr(inner.split(',').map(|s| scalar(s.trim())).collect());
    }
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Json::Str(t[1..t.len() - 1].to_string());
    }
    match t {
        "null" | "~" => return Json::Null,
        "true" | "True" => return Json::Bool(true),
        "false" | "False" => return Json::Bool(false),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Json::Int(i);
    }
    if let Ok(f) = t.parse::<f64>() {
        return Json::Num(f);
    }
    Json::Str(t.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_maps() {
        let y = "
benchmark:
  name: quickstart
  seed: 42
workload:
  rate: 500K
  nested:
    deep: true
";
        let v = parse(y).unwrap();
        assert_eq!(
            v.path(&["benchmark", "name"]).unwrap().as_str().unwrap(),
            "quickstart"
        );
        assert_eq!(v.path(&["benchmark", "seed"]).unwrap().as_i64(), Some(42));
        assert_eq!(
            v.path(&["workload", "rate"]).unwrap().as_str().unwrap(),
            "500K"
        );
        assert_eq!(
            v.path(&["workload", "nested", "deep"]).unwrap().as_bool(),
            Some(true)
        );
    }

    #[test]
    fn scalar_types() {
        let v = parse("a: 1\nb: 2.5\nc: yes_string\nd: \"quoted: x\"\ne: null\nf: false").unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "yes_string");
        assert_eq!(v.get("d").unwrap().as_str().unwrap(), "quoted: x");
        assert_eq!(v.get("e").unwrap(), &Json::Null);
        assert_eq!(v.get("f").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn block_list_of_scalars() {
        let v = parse("rates:\n  - 1M\n  - 2M\n  - 4M\n").unwrap();
        let arr = v.get("rates").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_str().unwrap(), "2M");
    }

    #[test]
    fn inline_list() {
        let v = parse("parallelism: [1, 2, 4, 8, 16]").unwrap();
        let arr = v.get("parallelism").unwrap().as_arr().unwrap();
        assert_eq!(arr.iter().filter_map(|x| x.as_i64()).collect::<Vec<_>>(), vec![1, 2, 4, 8, 16]);
    }

    #[test]
    fn list_of_mappings() {
        let y = "
experiments:
  - name: p1
    engine.parallelism: 1
  - name: p2
    engine.parallelism: 2
";
        let v = parse(y).unwrap();
        let arr = v.get("experiments").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "p1");
        assert_eq!(arr[1].get("engine.parallelism").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn comments_and_blanks() {
        let y = "# header\na: 1  # trailing\n\n# mid\nb: 2\n";
        let v = parse(y).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let v = parse("a: \"x # not a comment\"").unwrap();
        assert_eq!(v.get("a").unwrap().as_str().unwrap(), "x # not a comment");
    }

    #[test]
    fn empty_doc() {
        assert_eq!(parse("").unwrap(), Json::obj());
        assert_eq!(parse("# only comments\n").unwrap(), Json::obj());
    }

    #[test]
    fn error_has_line_number() {
        let err = parse("a:\n    b: 1\n  misdent: 2\n").unwrap_err();
        assert_eq!(err.line, 3);
    }

    #[test]
    fn pipeline_ops_block_parses_as_list_of_single_key_maps() {
        // The operator-chain spec shape (schema::parse_pipeline_spec
        // consumes this tree); each `- op:` item with a deeper-indented
        // block must become a single-key mapping.
        let y = "
engine:
  pipeline:
    ops:
      - filter:
          cmp: gt
          value: 26.0
      - emit: aggregates
";
        let v = parse(y).unwrap();
        let ops = v
            .path(&["engine", "pipeline", "ops"])
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(ops.len(), 2);
        let filter = ops[0].get("filter").expect("single-key op mapping");
        assert_eq!(filter.get("cmp").unwrap().as_str(), Some("gt"));
        assert_eq!(filter.get("value").unwrap().as_f64(), Some(26.0));
        assert_eq!(ops[1].get("emit").unwrap().as_str(), Some("aggregates"));
    }

    #[test]
    fn experiment_section_scalars_keep_their_types() {
        // The max-capacity `experiment:` section mixes floats, ints and
        // unit-suffixed strings; the parser must keep each distinct so the
        // schema layer can apply unit parsing where appropriate.
        let y = "
experiment:
  step_factor: 1.5
  max_iterations: 12
  start_rate: 250K
  max_p99: 500ms
";
        let v = parse(y).unwrap();
        assert_eq!(
            v.path(&["experiment", "step_factor"]).unwrap().as_f64(),
            Some(1.5)
        );
        assert_eq!(
            v.path(&["experiment", "max_iterations"]).unwrap().as_i64(),
            Some(12)
        );
        assert_eq!(
            v.path(&["experiment", "start_rate"]).unwrap().as_str(),
            Some("250K")
        );
        assert_eq!(
            v.path(&["experiment", "max_p99"]).unwrap().as_str(),
            Some("500ms")
        );
    }
}
