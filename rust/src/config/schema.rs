//! Typed configuration schema with defaults and validation.
//!
//! One `BenchConfig` drives every component (paper Sec. 3: the master
//! config is the only manual step).  All quantities accept human units
//! ("500K", "27B", "30s") via [`crate::util::units`].

use crate::engine::window::{AggKind, LatePolicy, WindowTime};
use crate::util::json::Json;
use crate::util::units::{parse_bytes, parse_count, parse_duration_micros};

/// Execution mode: real threads + real time, or discrete-event virtual time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Wall,
    Sim,
}

/// Workload generation pattern (paper Sec. 3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    Constant,
    Random,
    Burst,
}

/// Stream-processing framework personality (paper Sec. 3: Flink, Spark
/// Streaming and Kafka Streams are fully integrated).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Framework {
    Flink,
    Spark,
    KStreams,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::Flink => "flink",
            Framework::Spark => "spark",
            Framework::KStreams => "kstreams",
        }
    }
}

/// Processing pipeline class (paper Sec. 3.3) plus the fused extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineKind {
    PassThrough,
    CpuIntensive,
    MemIntensive,
    Fused,
}

impl PipelineKind {
    pub fn name(self) -> &'static str {
        match self {
            PipelineKind::PassThrough => "passthrough",
            PipelineKind::CpuIntensive => "cpu",
            PipelineKind::MemIntensive => "mem",
            PipelineKind::Fused => "fused",
        }
    }

    /// The paper pipeline expressed as an operator chain — the canonical
    /// spec [`crate::pipelines::StepFactory`] compiles when no explicit
    /// `pipeline: {ops: [...]}` spec is configured.  Window durations of 0
    /// inherit `engine.window` / `engine.slide` at compile time.
    pub fn canonical_spec(self) -> PipelineSpec {
        let ops = match self {
            PipelineKind::PassThrough => vec![OpSpec::Forward],
            PipelineKind::CpuIntensive => vec![OpSpec::CpuTransform, OpSpec::EmitEvents],
            PipelineKind::MemIntensive => vec![
                OpSpec::window(AggKind::Mean, 0, 0),
                OpSpec::EmitAggregates,
            ],
            PipelineKind::Fused => vec![
                OpSpec::CpuTransform,
                OpSpec::EmitEvents,
                OpSpec::window(AggKind::Mean, 0, 0),
                OpSpec::EmitAggregates,
            ],
        };
        PipelineSpec { ops }
    }
}

/// How rows move between pipeline stages split at `keyby` boundaries.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExchangeMode {
    /// Hash-routed inter-task exchange (the default): after a re-keying
    /// every row travels to the task that owns its derived key, so keyed
    /// state downstream sees the whole key group regardless of which
    /// broker partition produced the row.  Routing uses the same
    /// Fibonacci hash as broker partitioning
    /// ([`crate::broker::fib_slot`]).
    #[default]
    Hash,
    /// No exchange: rows stay on the task that polled them — the
    /// pre-exchange behaviour, under which per-key aggregates silently
    /// change with `engine.parallelism`.  Kept as an explicit opt-out for
    /// ablations and the regression suite.
    None,
}

impl ExchangeMode {
    pub fn name(self) -> &'static str {
        match self {
            ExchangeMode::Hash => "hash",
            ExchangeMode::None => "none",
        }
    }

    pub fn from_name(s: &str) -> Option<ExchangeMode> {
        match s {
            "hash" => Some(ExchangeMode::Hash),
            "none" | "off" => Some(ExchangeMode::None),
            _ => None,
        }
    }
}

/// Comparison operator for [`OpSpec::Filter`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    Gt,
    Ge,
    Lt,
    Le,
}

impl CmpOp {
    pub fn name(self) -> &'static str {
        match self {
            CmpOp::Gt => "gt",
            CmpOp::Ge => "ge",
            CmpOp::Lt => "lt",
            CmpOp::Le => "le",
        }
    }

    pub fn from_name(s: &str) -> Option<CmpOp> {
        match s {
            "gt" | ">" => Some(CmpOp::Gt),
            "ge" | ">=" => Some(CmpOp::Ge),
            "lt" | "<" => Some(CmpOp::Lt),
            "le" | "<=" => Some(CmpOp::Le),
            _ => None,
        }
    }

    pub fn eval(self, lhs: f32, rhs: f32) -> bool {
        match self {
            CmpOp::Gt => lhs > rhs,
            CmpOp::Ge => lhs >= rhs,
            CmpOp::Lt => lhs < rhs,
            CmpOp::Le => lhs <= rhs,
        }
    }
}

/// One operator in a declarative pipeline spec (the `pipeline: {ops: [...]}`
/// config form).  Compiled to a concrete operator by
/// [`crate::pipelines::Chain`].
#[derive(Clone, Debug, PartialEq)]
pub enum OpSpec {
    /// Forward raw broker records untouched (the pass-through baseline).
    /// Must be the only operator in its chain.
    Forward,
    /// Keep rows whose value compares true against `value`.
    Filter { cmp: CmpOp, value: f32 },
    /// Affine projection of the value: `v * scale + offset`.
    Map { scale: f32, offset: f32 },
    /// The paper's CPU-intensive transform: °C → °F plus alert counting
    /// against `engine.threshold_f`; HLO-accelerated when artifacts exist.
    CpuTransform,
    /// Re-key rows by `key % modulo` (shuffle-style regrouping).  With the
    /// exchange enabled, every `keyby` opens a new pipeline stage whose
    /// rows are hash-routed to the task owning the derived key;
    /// `parallelism` sets that stage's instance count (0 inherits
    /// `engine.parallelism`).
    KeyBy { modulo: u32, parallelism: u32 },
    /// Keyed sliding-window aggregation; 0 durations inherit
    /// `engine.window` / `engine.slide`.  Consumes event rows and emits
    /// aggregate rows downstream.  `time: event` switches pane assignment
    /// from arrival order to the record's generation timestamp, driven by
    /// a bounded-disorder watermark.
    Window {
        agg: AggKind,
        window_micros: u64,
        slide_micros: u64,
        /// Processing-time (default) or event-time pane assignment.
        time: WindowTime,
        /// Event time only: windows stay open until the watermark passes
        /// `end + allowed_lateness`.
        allowed_lateness_micros: u64,
        /// Event time only: what to do with records behind the watermark.
        late_policy: LatePolicy,
        /// Event time only: watermark bound (disorder slack); 0 inherits
        /// `max(workload.disorder.lateness, slide)` — the slide floor
        /// protects shuffle-only disorder from a degenerate tiny bound.
        watermark_micros: u64,
    },
    /// Keep the `k` largest aggregates per window.  Top-k selects across
    /// *all* keys of a window, so with the exchange enabled it runs in its
    /// own stage; `parallelism` 0 defaults that stage to a single global
    /// instance (the only width at which the selection sees every
    /// aggregate).
    TopK { k: usize, parallelism: u32 },
    /// Serialize rows as sensor events to the egestion topic (rows pass
    /// through unchanged, so a window may follow — the fused shape).
    EmitEvents,
    /// Serialize aggregate rows as compact JSON aggregate records.
    EmitAggregates,
    /// A user operator resolved by name against the
    /// [`crate::pipelines::OperatorRegistry`] at engine start.
    Custom { name: String, params: Json },
}

impl OpSpec {
    /// A processing-time window op (the common literal form; event-time
    /// windows set the extra fields explicitly).
    pub fn window(agg: AggKind, window_micros: u64, slide_micros: u64) -> OpSpec {
        OpSpec::Window {
            agg,
            window_micros,
            slide_micros,
            time: WindowTime::Processing,
            allowed_lateness_micros: 0,
            late_policy: LatePolicy::default(),
            watermark_micros: 0,
        }
    }

    /// Resolved watermark bound of an **event-time window** op: the
    /// explicit `watermark:`, else `max(workload.disorder.lateness,
    /// resolved slide)` — the single definition shared by the chain
    /// compiler (constructing the window's tracker) and the staged
    /// compiler (sizing the exchange source's liveness slack); the two
    /// must never drift apart.  `None` for every other op.
    pub fn event_watermark_bound(&self, cfg: &BenchConfig) -> Option<u64> {
        match self {
            OpSpec::Window {
                time: WindowTime::Event,
                slide_micros,
                watermark_micros,
                ..
            } => {
                if *watermark_micros > 0 {
                    Some(*watermark_micros)
                } else {
                    let s = if *slide_micros > 0 {
                        *slide_micros
                    } else {
                        cfg.engine.slide_micros
                    };
                    Some(cfg.workload.disorder.lateness_micros.max(s))
                }
            }
            _ => None,
        }
    }

    pub fn op_name(&self) -> &str {
        match self {
            OpSpec::Forward => "forward",
            OpSpec::Filter { .. } => "filter",
            OpSpec::Map { .. } => "map",
            OpSpec::CpuTransform => "cpu_transform",
            OpSpec::KeyBy { .. } => "keyby",
            OpSpec::Window { .. } => "window",
            OpSpec::TopK { .. } => "topk",
            OpSpec::EmitEvents => "emit_events",
            OpSpec::EmitAggregates => "emit_aggregates",
            OpSpec::Custom { name, .. } => name,
        }
    }
}

/// A declarative operator-chain pipeline (`engine.pipeline: {ops: [...]}`).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct PipelineSpec {
    pub ops: Vec<OpSpec>,
}

impl PipelineSpec {
    /// Display label, e.g. `chain[filter→keyby→window→topk→emit_aggregates]`.
    pub fn label(&self) -> String {
        let names: Vec<&str> = self.ops.iter().map(|o| o.op_name()).collect();
        format!("chain[{}]", names.join("→"))
    }

    /// The aggregator of the last window at or before op index `i`
    /// (drives the JSON field name of a downstream `emit_aggregates`).
    pub fn window_agg_before(&self, i: usize) -> Option<AggKind> {
        self.ops[..i].iter().rev().find_map(|o| match o {
            OpSpec::Window { agg, .. } => Some(*agg),
            _ => None,
        })
    }

    pub fn has_window(&self) -> bool {
        self.ops.iter().any(|o| matches!(o, OpSpec::Window { .. }))
    }

    /// Aggregator of the last window anywhere in the spec (used to carry
    /// the emit field name across stage boundaries).
    pub fn last_window_agg(&self) -> Option<AggKind> {
        self.window_agg_before(self.ops.len())
    }

    /// Decompose the chain into exchange-connected stages.
    ///
    /// A new stage opens after every `keyby` (rows must be re-routed to
    /// the task owning the derived key) and before every `topk` whose
    /// effective parallelism differs from the running stage's (top-k is a
    /// whole-window selection, so it defaults to one global instance).
    /// Stage 0 always runs at `engine_parallelism` — it is fed by the
    /// broker consumer group.  A chain without re-keying collapses to a
    /// single stage (no exchange).
    pub fn split_stages(&self, engine_parallelism: u32) -> Vec<StageSpec> {
        let par = engine_parallelism.max(1);
        let mut stages = vec![StageSpec {
            ops: Vec::new(),
            parallelism: par,
        }];
        for op in &self.ops {
            match op {
                OpSpec::KeyBy { parallelism, .. } => {
                    stages.last_mut().expect("nonempty").ops.push(op.clone());
                    let p = if *parallelism > 0 { *parallelism } else { par };
                    stages.push(StageSpec {
                        ops: Vec::new(),
                        parallelism: p.min(par),
                    });
                }
                OpSpec::TopK { parallelism, .. } => {
                    let declared = if *parallelism > 0 { *parallelism } else { 1 };
                    let p = declared.min(par);
                    let cur = stages.last_mut().expect("nonempty");
                    if cur.ops.is_empty() {
                        // Stage just opened by a keyby: adopt the top-k
                        // width instead of opening yet another stage.
                        cur.parallelism = p;
                        cur.ops.push(op.clone());
                    } else {
                        // Top-k always starts its own stage (whatever the
                        // parallelism), so the stage graph is identical at
                        // every `engine.parallelism` — the property the
                        // equivalence suite compares across.
                        stages.push(StageSpec {
                            ops: vec![op.clone()],
                            parallelism: p,
                        });
                    }
                }
                other => stages.last_mut().expect("nonempty").ops.push(other.clone()),
            }
        }
        // A trailing keyby opens a stage nothing flows into; fold it away.
        if stages.last().is_some_and(|s| s.ops.is_empty()) {
            stages.pop();
        }
        stages
    }

    /// Names of operators that need an `OperatorRegistry` to compile.
    /// Callers that can never supply one (the CLI) reject these up front,
    /// before a run is launched.
    pub fn custom_op_names(&self) -> Vec<&str> {
        self.ops
            .iter()
            .filter_map(|o| match o {
                OpSpec::Custom { name, .. } => Some(name.as_str()),
                _ => None,
            })
            .collect()
    }
}

/// One exchange-connected slice of an operator chain (see
/// [`PipelineSpec::split_stages`]): the ops executed between two keyed
/// routing boundaries, and the number of parallel instances hosting them.
#[derive(Clone, Debug, PartialEq)]
pub struct StageSpec {
    pub ops: Vec<OpSpec>,
    pub parallelism: u32,
}

#[derive(Clone, Debug)]
pub struct BenchSection {
    pub name: String,
    pub seed: u64,
    pub mode: ExecMode,
    pub duration_micros: u64,
    pub warmup_micros: u64,
}

#[derive(Clone, Debug)]
pub struct RandomPattern {
    pub min_rate: u64,
    pub max_rate: u64,
    pub min_pause_micros: u64,
    pub max_pause_micros: u64,
}

#[derive(Clone, Debug)]
pub struct BurstPattern {
    pub interval_micros: u64,
    pub burst_rate: u64,
}

/// Out-of-order workload model (`workload.disorder`): perturbs each
/// event's generation timestamp relative to its emission order, so the
/// stream arriving at the engine carries the disorder every real HPC
/// ingest path exhibits.  All knobs default to 0 (perfectly ordered).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DisorderSection {
    /// Maximum in-bound lateness (µs): delayed events are backdated by
    /// uniform(0, lateness].  An event-time window whose watermark bound
    /// covers this never drops an in-bound event.
    pub lateness_micros: u64,
    /// Fraction of events receiving an in-bound delay.
    pub late_fraction: f64,
    /// Fraction of events becoming "too-late" stragglers: backdated by
    /// lateness + uniform(0, straggler_lateness] — droppable by design.
    pub straggler_fraction: f64,
    /// Extra delay span for stragglers beyond `lateness` (µs).
    pub straggler_micros: u64,
    /// Reorder-buffer size: each emission slot releases a uniformly
    /// random pending event, shuffling emission order (0 disables).
    pub shuffle_window: usize,
}

impl DisorderSection {
    /// True when any disorder mechanism is active.
    pub fn enabled(&self) -> bool {
        (self.lateness_micros > 0 && self.late_fraction > 0.0)
            || self.straggler_fraction > 0.0
            || self.shuffle_window > 0
    }
}

#[derive(Clone, Debug)]
pub struct WorkloadSection {
    pub pattern: Pattern,
    /// Total offered load, events/second, across all generator instances.
    pub rate: u64,
    /// Deterministic count-bound generation: exactly this many events per
    /// run (split across instances), with synthetic generation timestamps
    /// spaced at the configured rate and temperatures quantized to 0.25 °C
    /// so downstream f32 aggregation is order-independent.  Two runs of
    /// the same config produce the byte-identical stream — the basis of
    /// the distributed-vs-local equivalence check.  0 = duration-bound
    /// wall-clock generation (the normal benchmark mode).
    pub events: u64,
    /// Serialized event size; paper minimum is 27 bytes.
    pub event_bytes: usize,
    /// Number of distinct sensor ids (keyed-state width K).
    pub sensors: u32,
    /// Zipf exponent for key skew; 0 = uniform.
    pub key_skew: f64,
    /// Hot-key set size: `hot_fraction` of the stream is drawn uniformly
    /// from sensor ids `[0, hot_keys)` — a concentrated hotspot on top of
    /// (or instead of) the Zipf tail.  0 disables.
    pub hot_keys: u32,
    /// Fraction of events hitting the hot-key set; 0 disables.
    pub hot_fraction: f64,
    pub random: RandomPattern,
    pub burst: BurstPattern,
    /// Out-of-order arrival model; disabled by default.
    pub disorder: DisorderSection,
}

#[derive(Clone, Debug)]
pub struct GeneratorSection {
    /// Rated capacity of one generator instance (events/s); the paper's
    /// generator does ~500K ev/s per instance and auto-scales instances.
    pub instance_capacity: u64,
    pub max_instances: u32,
    pub heap_bytes: u64,
}

#[derive(Clone, Debug)]
pub struct BrokerSection {
    pub partitions: u32,
    pub io_threads: u32,
    pub network_threads: u32,
    /// Per-partition bounded queue depth (records) — the backpressure knob.
    pub queue_depth: usize,
    pub heap_bytes: u64,
    /// Simulated per-record broker overhead (wall mode), microseconds.
    pub record_overhead_nanos: u64,
}

#[derive(Clone, Debug)]
pub struct EngineSection {
    pub framework: Framework,
    pub pipeline: PipelineKind,
    /// Explicit operator-chain spec; overrides `pipeline` when present.
    pub pipeline_spec: Option<PipelineSpec>,
    pub parallelism: u32,
    pub batch_size: usize,
    pub window_micros: u64,
    pub slide_micros: u64,
    pub threshold_f: f32,
    /// Execute pipeline compute through the AOT HLO artifacts (default) or
    /// through the native Rust reference ops (ablation baseline).
    pub use_hlo: bool,
    /// Micro-batch interval for the Spark personality.
    pub microbatch_micros: u64,
    /// Keyed exchange between pipeline stages split at `keyby`
    /// boundaries: `hash` (default) routes rows to the task owning the
    /// derived key; `none` keeps the pre-exchange task-local behaviour.
    pub exchange: ExchangeMode,
}

impl EngineSection {
    /// The operator chain this engine runs: the explicit spec when one is
    /// configured, else the canonical chain of the configured kind.
    pub fn effective_spec(&self) -> PipelineSpec {
        self.pipeline_spec
            .clone()
            .unwrap_or_else(|| self.pipeline.canonical_spec())
    }

    /// Human-readable pipeline name for reports: the kind name for the
    /// paper pipelines, a `chain[...]` label for explicit specs.
    pub fn pipeline_label(&self) -> String {
        match &self.pipeline_spec {
            None => self.pipeline.name().to_string(),
            Some(spec) => spec.label(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct MetricsSection {
    pub sample_interval_micros: u64,
    pub out_dir: String,
    /// When non-empty, the egest drainer dumps every final output record
    /// to this file as sorted canonical `gen_ts,key,payload-hex` lines —
    /// the byte-comparable "final aggregates" artifact the equivalence
    /// suites diff across execution modes.  Empty disables the dump.
    pub egest_dump: String,
}

/// Aligned-checkpointing controls (the `checkpoint:` section).
///
/// When `interval` is nonzero the run is divided into epochs of that
/// length; at the first batch boundary past each epoch edge every engine
/// task snapshots its operator state and consumer offsets into the
/// [`crate::engine::CheckpointCoordinator`], which commits the epoch to a
/// versioned, CRC-guarded file once all tasks have contributed.  Offsets
/// are only committed to the broker group for epochs whose checkpoint
/// file has durably committed, so a restore can always replay every
/// record processed after the snapshot.
#[derive(Clone, Debug)]
pub struct CheckpointSection {
    /// Checkpoint epoch length in µs; 0 disables checkpointing.
    pub interval_micros: u64,
    /// Directory for checkpoint files; empty string resolves to
    /// `<metrics.out_dir>/checkpoints` (see
    /// [`BenchConfig::checkpoint_dir`]).
    pub dir: String,
    /// How many committed checkpoints to retain on disk (older files are
    /// pruned); 0 keeps every checkpoint.
    pub retain: usize,
}

impl CheckpointSection {
    /// Whether checkpointing is configured at all.
    pub fn enabled(&self) -> bool {
        self.interval_micros > 0
    }
}

/// One fault from the declarative schedule (the `fault.schedule:` list).
#[derive(Clone, Debug, PartialEq)]
pub enum FaultKind {
    /// Abort engine task `task` — no window flush, no offset commit — and
    /// with it the whole incarnation (a process-death model: every task
    /// slot dies and the supervisor restarts the fleet).
    KillTask { task: u32 },
    /// Stall task `task` for `duration` without killing it: the task
    /// stops polling and stops publishing heartbeats, so only the
    /// watchdog's heartbeat deadline can notice.
    HangTask { task: u32 },
    /// Freeze one ingest partition for `duration`: fetches see no data,
    /// producers back-pressure against the buffered log.
    StallPartition { partition: u32 },
    /// Generators emit malformed/truncated payloads for `fraction` of the
    /// stream while the fault is active (`duration` 0 = the whole run).
    PoisonRecords { fraction: f64 },
    /// A distributed-run peer (worker process) vanished mid-run: its
    /// transport link died or its heartbeat went stale.  Not schedulable
    /// from YAML — the link supervisor reports it as a detected fault
    /// (results.json `faults[]`) when a TCP peer disconnects.
    PeerDisconnect { worker: u32 },
}

impl FaultKind {
    /// Schedule-key name, as written in YAML and in results.json.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::KillTask { .. } => "kill_task",
            FaultKind::HangTask { .. } => "hang_task",
            FaultKind::StallPartition { .. } => "stall_partition",
            FaultKind::PoisonRecords { .. } => "poison_records",
            FaultKind::PeerDisconnect { .. } => "peer_disconnect",
        }
    }

    /// Human-readable injection target ("task 1", "partition 2", …).
    pub fn target(&self) -> String {
        match self {
            FaultKind::KillTask { task } | FaultKind::HangTask { task } => format!("task {task}"),
            FaultKind::StallPartition { partition } => format!("partition {partition}"),
            FaultKind::PoisonRecords { fraction } => format!("fraction {fraction}"),
            FaultKind::PeerDisconnect { worker } => format!("worker {worker}"),
        }
    }
}

/// One timed entry in the fault schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Offset from "all tasks ready" at which the fault fires, µs.
    pub at_micros: u64,
    /// How long the fault holds (hang/stall/poison); 0 for instantaneous
    /// faults (kill) and "whole run" for poison.
    pub duration_micros: u64,
    /// Per-fault RNG seed (poison sampling); 0 inherits `benchmark.seed`.
    pub seed: u64,
}

impl FaultSpec {
    /// Whether healing this fault requires a supervised engine restart
    /// (kill and hang do; stall and poison degrade in place).
    pub fn needs_restart(&self) -> bool {
        matches!(
            self.kind,
            FaultKind::KillTask { .. } | FaultKind::HangTask { .. }
        )
    }
}

/// Fault-injection plan (the `fault:` section): a declarative schedule of
/// timed faults injected by the in-run supervisor, which detects dead and
/// hung tasks by heartbeat deadline and heals them by warm restore from
/// the latest committed checkpoint (bounded retries, exponential
/// backoff), degrading to a counted cold start when checkpoints are
/// unusable.  Drives the `faults[]` + `resilience` blocks in
/// results.json.  The legacy single-kill form (`kill_task`/`kill_after`)
/// still parses and becomes a one-entry schedule.
#[derive(Clone, Debug)]
pub struct FaultSection {
    /// Legacy form: engine task id to kill; must be < `engine.parallelism`.
    pub kill_task: u32,
    /// Legacy form: run offset at which the kill fires, µs from engine
    /// start; 0 disables it (the `schedule:` list is the general form).
    pub kill_after_micros: u64,
    /// The declarative fault schedule (see [`FaultSpec`]).
    pub schedule: Vec<FaultSpec>,
    /// Restore operator state and offsets from the latest committed
    /// checkpoint after a kill/hang heal.  A missing or wholly corrupt
    /// checkpoint directory degrades to a cold start at runtime (counted
    /// in results.json); `restore: false` forces the cold start.
    pub restore: bool,
    /// Watchdog deadline: a task whose last heartbeat is older than this
    /// is declared hung and the incarnation is torn down for a restart.
    pub heartbeat_timeout_micros: u64,
    /// Supervisor retry budget: give up (error out) after this many
    /// restarts in one run.
    pub max_restarts: u32,
    /// Initial supervisor backoff before a restart; doubles per restart.
    pub backoff_micros: u64,
}

impl FaultSection {
    /// Whether any fault is planned for this run.
    pub fn enabled(&self) -> bool {
        self.kill_after_micros > 0 || !self.schedule.is_empty()
    }

    /// The full schedule with the legacy single-kill form merged in,
    /// sorted by injection time.
    pub fn plan(&self) -> Vec<FaultSpec> {
        let mut plan = Vec::new();
        if self.kill_after_micros > 0 {
            plan.push(FaultSpec {
                kind: FaultKind::KillTask {
                    task: self.kill_task,
                },
                at_micros: self.kill_after_micros,
                duration_micros: 0,
                seed: 0,
            });
        }
        plan.extend(self.schedule.iter().cloned());
        plan.sort_by_key(|f| f.at_micros);
        plan
    }

    /// The poison windows of the plan (the generator applies these).
    pub fn poison_plan(&self) -> Vec<FaultSpec> {
        self.plan()
            .into_iter()
            .filter(|f| matches!(f.kind, FaultKind::PoisonRecords { .. }))
            .collect()
    }

    /// Whether the plan contains a fault healed by a supervised restart.
    pub fn has_restart_faults(&self) -> bool {
        self.plan().iter().any(|f| f.needs_restart())
    }
}

/// Max-capacity experiment controls (the `experiment:` section).
///
/// Drives [`crate::experiment::MaxCapacityDriver`]: an escalation loop that
/// multiplies the offered load by `step_factor` each iteration until the
/// sustainability predicate fails, then binary-searches the knee for
/// `refine_steps` rounds.  Sustainability follows the stepped-load
/// definition of Karimov et al. / ShuffleBench: the engine keeps up with
/// the offered rate without a growing backlog or runaway latency.
#[derive(Clone, Debug)]
pub struct ExperimentSection {
    /// Initial target rate (events/s) for the escalation loop;
    /// 0 = inherit `workload.rate`.
    pub start_rate: u64,
    /// Multiplicative step applied to the target rate each escalation
    /// round; must be > 1.
    pub step_factor: f64,
    /// Maximum escalation iterations before the sweep gives up looking
    /// for the knee.
    pub max_iterations: u32,
    /// Binary-search refinement rounds once the knee is bracketed.
    pub refine_steps: u32,
    /// A run is sustainable only if `processed_rate >= sustain_ratio *
    /// offered_rate` (and the fleet itself achieved `sustain_ratio` of the
    /// target).
    pub sustain_ratio: f64,
    /// p99 end-to-end latency bound in µs; 0 disables the check.
    pub max_p99_micros: u64,
    /// Bound on latency drift across the run: mean p50 of the second half
    /// of the timeline may be at most this multiple of the first half.
    /// 0 disables; values in (0, 1) are rejected.
    pub max_latency_growth: f64,
    /// Per-iteration measured duration; 0 = inherit `benchmark.duration`.
    pub iteration_duration_micros: u64,
    /// Timeline samples earlier than this offset from the start of each
    /// iteration are discarded before evaluating sustainability;
    /// 0 = inherit `benchmark.warmup`.
    pub warmup_discard_micros: u64,
    /// A run is unsustainable when more than this fraction of processed
    /// events arrived behind the watermark (late + dropped, summed across
    /// event-time operators); 0 disables the check.
    pub max_late_fraction: f64,
    /// A run is unsustainable when the supervisor restarted the engine
    /// more than this many times; 0 disables the check (a strict
    /// no-restart SLO is `min_availability: 1.0`).
    pub max_restarts: u32,
    /// Availability floor: a run is unsustainable when
    /// `1 - downtime/elapsed` falls below this; 0 disables the check.
    pub min_availability: f64,
}

#[derive(Clone, Debug)]
pub struct SlurmSection {
    pub enabled: bool,
    pub nodes: u32,
    pub cpus_per_task: u32,
    pub mem_bytes: u64,
    pub time_limit_micros: u64,
    pub partition: String,
}

/// How benchmark data moves between components (the `cluster:` section).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportMode {
    /// Everything in one process over shared-memory channels (default).
    Local,
    /// Broker, generators, and engine run as separate worker processes
    /// connected over TCP; `sprobench run` becomes the driver.
    Tcp,
}

impl TransportMode {
    pub fn name(&self) -> &'static str {
        match self {
            TransportMode::Local => "local",
            TransportMode::Tcp => "tcp",
        }
    }

    pub fn from_name(name: &str) -> Option<TransportMode> {
        match name {
            "local" => Some(TransportMode::Local),
            "tcp" => Some(TransportMode::Tcp),
            _ => None,
        }
    }
}

/// Distributed-execution controls (the `cluster:` section).
///
/// With `transport: tcp`, `sprobench run` acts as the driver: it binds a
/// control listener, waits for one broker worker, `generators` generator
/// workers, and one engine worker (spawning them locally as child
/// `sprobench worker` processes when `spawn_workers` is on — the
/// single-node loopback layout; under SLURM, `srun` launches them and
/// `spawn_workers` is off), distributes the resolved config, barriers
/// the fleet, and merges the per-worker result fragments into
/// results.json with a `transport` block.
#[derive(Clone, Debug)]
pub struct ClusterSection {
    pub transport: TransportMode,
    /// Driver control-plane bind address (`host:port`; port 0 = ephemeral).
    pub driver_bind: String,
    /// Broker data-plane bind address advertised to the other workers.
    pub data_bind: String,
    /// Dedicated generator worker processes.  0 colocates the generator
    /// fleet with the broker worker (the 3-process loopback layout).
    pub generators: u32,
    /// Driver spawns local worker processes itself (loopback runs).
    pub spawn_workers: bool,
    /// Worker→driver and data-plane connect deadline, µs.
    pub connect_timeout_micros: u64,
    /// Gather/READY-barrier deadline, µs (covers pipeline compilation).
    pub ready_timeout_micros: u64,
}

/// The master configuration: one file controls every component.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub bench: BenchSection,
    pub workload: WorkloadSection,
    pub generators: GeneratorSection,
    pub broker: BrokerSection,
    pub engine: EngineSection,
    pub metrics: MetricsSection,
    pub checkpoint: CheckpointSection,
    pub fault: FaultSection,
    pub experiment: ExperimentSection,
    pub slurm: SlurmSection,
    pub cluster: ClusterSection,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            bench: BenchSection {
                name: "bench".into(),
                seed: 42,
                mode: ExecMode::Wall,
                duration_micros: 10_000_000,
                warmup_micros: 1_000_000,
            },
            workload: WorkloadSection {
                pattern: Pattern::Constant,
                rate: 100_000,
                event_bytes: 27,
                sensors: 1024,
                key_skew: 0.0,
                hot_keys: 0,
                hot_fraction: 0.0,
                random: RandomPattern {
                    min_rate: 50_000,
                    max_rate: 200_000,
                    min_pause_micros: 1_000,
                    max_pause_micros: 10_000,
                },
                burst: BurstPattern {
                    interval_micros: 1_000_000,
                    burst_rate: 1_000_000,
                },
                disorder: DisorderSection::default(),
            },
            generators: GeneratorSection {
                instance_capacity: 500_000,
                max_instances: 64,
                heap_bytes: 2_000_000_000,
            },
            broker: BrokerSection {
                partitions: 4,
                io_threads: 4,
                network_threads: 2,
                queue_depth: 65_536,
                heap_bytes: 5_000_000_000,
                record_overhead_nanos: 0,
            },
            engine: EngineSection {
                framework: Framework::Flink,
                pipeline: PipelineKind::CpuIntensive,
                pipeline_spec: None,
                parallelism: 4,
                batch_size: 1024,
                window_micros: 10_000_000,
                slide_micros: 2_000_000,
                threshold_f: 80.0,
                use_hlo: true,
                microbatch_micros: 100_000,
                exchange: ExchangeMode::Hash,
            },
            metrics: MetricsSection {
                sample_interval_micros: 1_000_000,
                out_dir: "runs".into(),
            },
            checkpoint: CheckpointSection {
                interval_micros: 0,
                dir: String::new(),
                retain: 2,
            },
            fault: FaultSection {
                kill_task: 0,
                kill_after_micros: 0,
                schedule: Vec::new(),
                restore: true,
                heartbeat_timeout_micros: 250_000,
                max_restarts: 3,
                backoff_micros: 50_000,
            },
            experiment: ExperimentSection {
                start_rate: 0,
                step_factor: 2.0,
                max_iterations: 8,
                refine_steps: 4,
                sustain_ratio: 0.95,
                max_p99_micros: 0,
                max_latency_growth: 0.0,
                iteration_duration_micros: 0,
                warmup_discard_micros: 0,
                max_late_fraction: 0.0,
                max_restarts: 0,
                min_availability: 0.0,
            },
            slurm: SlurmSection {
                enabled: false,
                nodes: 1,
                cpus_per_task: 16,
                mem_bytes: 200_000_000_000,
                time_limit_micros: 1_800_000_000,
                partition: "barnard".into(),
            },
            cluster: ClusterSection {
                transport: TransportMode::Local,
                driver_bind: "127.0.0.1:0".into(),
                data_bind: "127.0.0.1:0".into(),
                generators: 0,
                spawn_workers: true,
                connect_timeout_micros: 15_000_000,
                ready_timeout_micros: 120_000_000,
            },
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ConfigError> {
    Err(ConfigError(msg.into()))
}

// --- helpers to read Json fields with unit parsing --------------------------

fn get_str(j: &Json, key: &str, default: &str) -> String {
    j.get(key)
        .and_then(|v| v.as_str())
        .unwrap_or(default)
        .to_string()
}

fn get_u64(j: &Json, key: &str, default: u64) -> Result<u64, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Int(i)) if *i >= 0 => Ok(*i as u64),
        Some(Json::Num(f)) if *f >= 0.0 => Ok(*f as u64),
        Some(Json::Str(s)) => parse_count(s).map_err(ConfigError),
        Some(other) => err(format!("field '{key}': expected count, got {other:?}")),
    }
}

fn get_u32(j: &Json, key: &str, default: u32) -> Result<u32, ConfigError> {
    let v = get_u64(j, key, default as u64)?;
    u32::try_from(v).map_err(|_| ConfigError(format!("field '{key}': {v} exceeds u32 range")))
}

fn get_bytes(j: &Json, key: &str, default: u64) -> Result<u64, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Int(i)) if *i >= 0 => Ok(*i as u64),
        Some(Json::Str(s)) => parse_bytes(s).map_err(ConfigError),
        Some(other) => err(format!("field '{key}': expected size, got {other:?}")),
    }
}

fn get_duration(j: &Json, key: &str, default: u64) -> Result<u64, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Int(i)) if *i >= 0 => Ok(*i as u64 * 1_000_000),
        Some(Json::Num(f)) if *f >= 0.0 => Ok((*f * 1e6) as u64),
        Some(Json::Str(s)) => parse_duration_micros(s).map_err(ConfigError),
        Some(other) => err(format!("field '{key}': expected duration, got {other:?}")),
    }
}

fn get_f64(j: &Json, key: &str, default: f64) -> Result<f64, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| ConfigError(format!("field '{key}': expected number"))),
    }
}

fn get_bool(j: &Json, key: &str, default: bool) -> Result<bool, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| ConfigError(format!("field '{key}': expected bool"))),
    }
}

fn section(j: &Json, key: &str) -> Json {
    j.get(key).cloned().unwrap_or_else(Json::obj)
}

// --- fault schedules ---------------------------------------------------------

/// The fault-schedule grammar, appended to every schedule parse error.
pub fn fault_grammar() -> &'static str {
    "fault.schedule accepts a list of timed faults:
  schedule:
    - kill_task: 1        # abort task 1 (whole incarnation dies)
      at: 500ms           # offset from all-tasks-ready
    - hang_task: 0        # stall task 0: no polling, no heartbeats
      at: 900ms
      duration: 300ms     # how long the stall holds (required)
    - stall_partition: 2  # freeze ingest partition 2
      at: 1s
      duration: 200ms     # required
    - poison_records: 0.05  # 5% of generated payloads malformed
      at: 0s              # optional window start
      duration: 0         # 0 = the whole run
      seed: 7             # optional; 0 inherits benchmark.seed
(see docs/ARCHITECTURE.md §Fault injection & supervision)"
}

/// Parse one `fault.schedule` entry: a mapping with exactly one fault key
/// (`kill_task`/`hang_task`/`stall_partition`/`poison_records`) plus
/// optional `at`/`duration`/`seed` siblings.
fn parse_fault(i: usize, entry: &Json) -> Result<FaultSpec, ConfigError> {
    let at_entry = |what: &str| format!("fault.schedule[{i}]: {what}");
    let Json::Obj(_) = entry else {
        return err(format!(
            "{}\n{}",
            at_entry(&format!("expected a fault mapping, got {entry:?}")),
            fault_grammar()
        ));
    };
    let kinds = [
        "kill_task",
        "hang_task",
        "stall_partition",
        "poison_records",
    ];
    let mut found: Vec<&str> = kinds
        .iter()
        .copied()
        .filter(|k| !matches!(entry.get(k), None | Some(Json::Null)))
        .collect();
    // YAML's flattened single-key form (`- kill_task: 1` with siblings)
    // can parse the kind key's value as Null; accept it as "present" when
    // no valued kind key exists.
    if found.is_empty() {
        found = kinds
            .iter()
            .copied()
            .filter(|k| entry.get(k).is_some())
            .collect();
    }
    let kind_key = match found.as_slice() {
        [one] => *one,
        [] => {
            return err(format!(
                "{}\n{}",
                at_entry(&format!(
                    "no fault kind in {entry:?} — write one of kill_task, hang_task, \
                     stall_partition or poison_records per list item"
                )),
                fault_grammar()
            ))
        }
        many => {
            return err(format!(
                "{}\n{}",
                at_entry(&format!(
                    "one fault per list item, found {}",
                    many.join(" + ")
                )),
                fault_grammar()
            ))
        }
    };
    let kind = match kind_key {
        "kill_task" => FaultKind::KillTask {
            task: get_u32(entry, "kill_task", 0)?,
        },
        "hang_task" => FaultKind::HangTask {
            task: get_u32(entry, "hang_task", 0)?,
        },
        "stall_partition" => FaultKind::StallPartition {
            partition: get_u32(entry, "stall_partition", 0)?,
        },
        "poison_records" => {
            let fraction = get_f64(entry, "poison_records", f64::NAN)?;
            if !(fraction > 0.0 && fraction <= 1.0) {
                return err(at_entry(&format!(
                    "poison_records fraction must be in (0, 1] (got {fraction})"
                )));
            }
            FaultKind::PoisonRecords { fraction }
        }
        _ => unreachable!("kind_key comes from the kinds table"),
    };
    let spec = FaultSpec {
        kind,
        at_micros: get_duration(entry, "at", 0)?,
        duration_micros: get_duration(entry, "duration", 0)?,
        seed: get_u64(entry, "seed", 0)?,
    };
    if spec.duration_micros == 0
        && matches!(
            spec.kind,
            FaultKind::HangTask { .. } | FaultKind::StallPartition { .. }
        )
    {
        return err(at_entry(&format!(
            "{} needs `duration:` > 0 (how long the stall holds)",
            spec.kind.name()
        )));
    }
    Ok(spec)
}

// --- operator-chain pipeline specs ------------------------------------------

/// The spec grammar, appended to every pipeline config error so a typo
/// never produces an opaque parse failure.
pub fn pipeline_grammar() -> &'static str {
    "engine.pipeline accepts a kind — passthrough | cpu | mem | fused — or an \
operator-chain spec:
  pipeline:
    ops:
      - filter:
          cmp: gt          # gt | ge | lt | le
          value: 26.0
      - keyby:
          modulo: 64
          parallelism: 4   # instances of the stage this keyby opens;
                           # omit to inherit engine.parallelism
      - window:
          agg: mean        # mean | sum | min | max | count
          window: 2s       # omit to inherit engine.window; slide must divide window
          slide: 1s        # omit to inherit engine.slide
          time: event      # processing (default) | event
          allowed_lateness: 250ms   # event time: hold windows open past end
          late_policy: merge_if_open  # drop | side_count | merge_if_open
          watermark: 250ms # event time: disorder slack; omit to inherit
                           # max(workload.disorder.lateness, slide)
      - topk:
          k: 10
          parallelism: 1   # top-k runs in its own single global stage
                           # (1 or omitted; partial top-k is rejected)
      - emit: aggregates   # or: events
built-in ops: forward, filter(cmp,value), map(scale,offset), cpu_transform, \
keyby(modulo,parallelism), window(agg,window,slide,time,allowed_lateness,\
late_policy,watermark), topk(k,parallelism), emit(events|aggregates); any \
other name resolves against the custom OperatorRegistry at engine start.  \
Chains are split into stages at each keyby; `engine.exchange: hash` \
(default) hash-routes rows between stages so keyed state sees whole key \
groups, `none` keeps rows task-local \
(see docs/ARCHITECTURE.md §Pipeline operator chains, §Time semantics and \
§Exchange & keyed state)"
}

/// Parse an operator-chain spec from its JSON tree: either `{ops: [...]}`
/// or a bare ops list (the `--pipeline-spec` file form).
pub fn parse_pipeline_spec(j: &Json) -> Result<PipelineSpec, ConfigError> {
    let ops_json: &[Json] = match j {
        Json::Arr(a) => a.as_slice(),
        _ => j
            .get("ops")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| {
                ConfigError(format!(
                    "engine.pipeline: an operator-chain spec needs an `ops:` list\n{}",
                    pipeline_grammar()
                ))
            })?,
    };
    if ops_json.is_empty() {
        return err(format!(
            "engine.pipeline.ops: the chain is empty\n{}",
            pipeline_grammar()
        ));
    }
    let ops = ops_json
        .iter()
        .enumerate()
        .map(|(i, entry)| parse_op(i, entry))
        .collect::<Result<Vec<_>, _>>()?;
    Ok(PipelineSpec { ops })
}

fn parse_op(i: usize, entry: &Json) -> Result<OpSpec, ConfigError> {
    match entry {
        Json::Str(s) => build_op(i, s, &Json::obj()),
        Json::Obj(m) => {
            // Single-key form (`- filter: {…}` nested block), or the
            // flattened YAML form where the op key parsed to null and its
            // parameters landed as siblings.
            let (name, params) = if m.len() == 1 {
                let (k, v) = m.iter().next().expect("len checked");
                (k.clone(), v.clone())
            } else {
                let mut nulls = m.iter().filter(|(_, v)| matches!(v, Json::Null));
                match (nulls.next(), nulls.next()) {
                    (Some((k, _)), None) => {
                        let mut rest = m.clone();
                        let k = k.clone();
                        rest.remove(&k);
                        (k, Json::Obj(rest))
                    }
                    _ => {
                        return err(format!(
                            "engine.pipeline.ops[{i}]: cannot identify the operator key in \
                             {entry:?} — write one op per list item\n{}",
                            pipeline_grammar()
                        ))
                    }
                }
            };
            build_op(i, &name, &params)
        }
        other => err(format!(
            "engine.pipeline.ops[{i}]: expected an operator name or mapping, got {other:?}\n{}",
            pipeline_grammar()
        )),
    }
}

fn build_op(i: usize, name: &str, params: &Json) -> Result<OpSpec, ConfigError> {
    let at = |what: &str| format!("engine.pipeline.ops[{i}] ({name}): {what}");
    match name {
        "forward" => Ok(OpSpec::Forward),
        "cpu_transform" => Ok(OpSpec::CpuTransform),
        "emit_events" => Ok(OpSpec::EmitEvents),
        "emit_aggregates" => Ok(OpSpec::EmitAggregates),
        "emit" => {
            let kind = params
                .as_str()
                .or_else(|| params.get("kind").and_then(|v| v.as_str()))
                .unwrap_or("events");
            match kind {
                "events" => Ok(OpSpec::EmitEvents),
                "aggregates" => Ok(OpSpec::EmitAggregates),
                other => err(at(&format!(
                    "unknown emit kind '{other}' — expected events or aggregates"
                ))),
            }
        }
        "filter" => {
            let cmp_name = params
                .get("cmp")
                .or_else(|| params.get("op"))
                .and_then(|v| v.as_str())
                .unwrap_or("gt");
            let cmp = CmpOp::from_name(cmp_name).ok_or_else(|| {
                ConfigError(at(&format!(
                    "unknown cmp '{cmp_name}' — expected gt, ge, lt or le"
                )))
            })?;
            let value = get_f64(params, "value", f64::NAN)? as f32;
            if !value.is_finite() {
                return err(at("needs a finite `value:`"));
            }
            Ok(OpSpec::Filter { cmp, value })
        }
        "map" => {
            let scale = get_f64(params, "scale", 1.0)? as f32;
            let offset = get_f64(params, "offset", 0.0)? as f32;
            if !scale.is_finite() || !offset.is_finite() {
                return err(at("scale/offset must be finite"));
            }
            Ok(OpSpec::Map { scale, offset })
        }
        "keyby" => {
            let modulo = get_u64(params, "modulo", 0)? as u32;
            if modulo == 0 {
                return err(at("needs `modulo:` > 0"));
            }
            Ok(OpSpec::KeyBy {
                modulo,
                parallelism: get_u32(params, "parallelism", 0)?,
            })
        }
        "window" => {
            let agg_name = params
                .get("agg")
                .and_then(|v| v.as_str())
                .unwrap_or("mean");
            let agg = AggKind::from_name(agg_name).ok_or_else(|| {
                ConfigError(at(&format!(
                    "unknown agg '{agg_name}' — expected mean, sum, min, max or count"
                )))
            })?;
            let time_name = params
                .get("time")
                .and_then(|v| v.as_str())
                .unwrap_or("processing");
            let time = WindowTime::from_name(time_name).ok_or_else(|| {
                ConfigError(at(&format!(
                    "unknown time '{time_name}' — expected processing or event"
                )))
            })?;
            let policy_name = params
                .get("late_policy")
                .and_then(|v| v.as_str())
                .unwrap_or("drop");
            let late_policy = LatePolicy::from_name(policy_name).ok_or_else(|| {
                ConfigError(at(&format!(
                    "unknown late_policy '{policy_name}' — expected drop, side_count \
                     or merge_if_open"
                )))
            })?;
            let allowed_lateness_micros = get_duration(params, "allowed_lateness", 0)?;
            let watermark_micros = get_duration(params, "watermark", 0)?;
            if time == WindowTime::Processing
                && (allowed_lateness_micros > 0
                    || watermark_micros > 0
                    || params.get("late_policy").is_some())
            {
                return err(at(
                    "allowed_lateness/late_policy/watermark apply only to \
                     `time: event` windows",
                ));
            }
            Ok(OpSpec::Window {
                agg,
                window_micros: get_duration(params, "window", 0)?,
                slide_micros: get_duration(params, "slide", 0)?,
                time,
                allowed_lateness_micros,
                late_policy,
                watermark_micros,
            })
        }
        "topk" => {
            let k = get_u64(params, "k", 0)? as usize;
            if k == 0 {
                return err(at("needs `k:` > 0"));
            }
            Ok(OpSpec::TopK {
                k,
                parallelism: get_u32(params, "parallelism", 0)?,
            })
        }
        custom => Ok(OpSpec::Custom {
            name: custom.to_string(),
            params: params.clone(),
        }),
    }
}

impl BenchConfig {
    /// Build a config from a parsed YAML/JSON tree, applying defaults.
    pub fn from_json(root: &Json) -> Result<Self, ConfigError> {
        let d = BenchConfig::default();

        let b = section(root, "benchmark");
        let bench = BenchSection {
            name: get_str(&b, "name", &d.bench.name),
            seed: get_u64(&b, "seed", d.bench.seed)?,
            mode: match get_str(&b, "mode", "wall").as_str() {
                "wall" => ExecMode::Wall,
                "sim" => ExecMode::Sim,
                other => return err(format!("benchmark.mode: unknown '{other}'")),
            },
            duration_micros: get_duration(&b, "duration", d.bench.duration_micros)?,
            warmup_micros: get_duration(&b, "warmup", d.bench.warmup_micros)?,
        };

        let w = section(root, "workload");
        let rnd = section(&w, "random");
        let burst = section(&w, "burst");
        let dis = section(&w, "disorder");
        let workload = WorkloadSection {
            pattern: match get_str(&w, "pattern", "constant").as_str() {
                "constant" => Pattern::Constant,
                "random" => Pattern::Random,
                "burst" => Pattern::Burst,
                other => return err(format!("workload.pattern: unknown '{other}'")),
            },
            rate: get_u64(&w, "rate", d.workload.rate)?,
            events: get_u64(&w, "events", d.workload.events)?,
            event_bytes: get_bytes(&w, "event_bytes", d.workload.event_bytes as u64)? as usize,
            sensors: get_u64(&w, "sensors", d.workload.sensors as u64)? as u32,
            key_skew: get_f64(&w, "key_skew", d.workload.key_skew)?,
            hot_keys: get_u32(&w, "hot_keys", d.workload.hot_keys)?,
            hot_fraction: get_f64(&w, "hot_fraction", d.workload.hot_fraction)?,
            random: RandomPattern {
                min_rate: get_u64(&rnd, "min_rate", d.workload.random.min_rate)?,
                max_rate: get_u64(&rnd, "max_rate", d.workload.random.max_rate)?,
                min_pause_micros: get_duration(
                    &rnd,
                    "min_pause",
                    d.workload.random.min_pause_micros,
                )?,
                max_pause_micros: get_duration(
                    &rnd,
                    "max_pause",
                    d.workload.random.max_pause_micros,
                )?,
            },
            burst: BurstPattern {
                interval_micros: get_duration(&burst, "interval", d.workload.burst.interval_micros)?,
                burst_rate: get_u64(&burst, "burst_rate", d.workload.burst.burst_rate)?,
            },
            disorder: DisorderSection {
                lateness_micros: get_duration(&dis, "lateness", d.workload.disorder.lateness_micros)?,
                late_fraction: get_f64(&dis, "late_fraction", d.workload.disorder.late_fraction)?,
                straggler_fraction: get_f64(
                    &dis,
                    "straggler_fraction",
                    d.workload.disorder.straggler_fraction,
                )?,
                straggler_micros: get_duration(
                    &dis,
                    "straggler_lateness",
                    d.workload.disorder.straggler_micros,
                )?,
                shuffle_window: get_u64(
                    &dis,
                    "shuffle_window",
                    d.workload.disorder.shuffle_window as u64,
                )? as usize,
            },
        };

        let g = section(root, "generators");
        let generators = GeneratorSection {
            instance_capacity: get_u64(&g, "instance_capacity", d.generators.instance_capacity)?,
            max_instances: get_u64(&g, "max_instances", d.generators.max_instances as u64)? as u32,
            heap_bytes: get_bytes(&g, "heap", d.generators.heap_bytes)?,
        };

        let br = section(root, "broker");
        let broker = BrokerSection {
            partitions: get_u64(&br, "partitions", d.broker.partitions as u64)? as u32,
            io_threads: get_u64(&br, "io_threads", d.broker.io_threads as u64)? as u32,
            network_threads: get_u64(&br, "network_threads", d.broker.network_threads as u64)?
                as u32,
            queue_depth: get_u64(&br, "queue_depth", d.broker.queue_depth as u64)? as usize,
            heap_bytes: get_bytes(&br, "heap", d.broker.heap_bytes)?,
            record_overhead_nanos: get_u64(
                &br,
                "record_overhead_nanos",
                d.broker.record_overhead_nanos,
            )?,
        };

        let e = section(root, "engine");
        let (pipeline, pipeline_spec) = match e.get("pipeline") {
            None | Some(Json::Null) => (d.engine.pipeline, None),
            Some(Json::Str(s)) => (
                match s.as_str() {
                    "passthrough" => PipelineKind::PassThrough,
                    "cpu" => PipelineKind::CpuIntensive,
                    "mem" => PipelineKind::MemIntensive,
                    "fused" => PipelineKind::Fused,
                    other => {
                        return err(format!(
                            "engine.pipeline: unknown kind '{other}'\n{}",
                            pipeline_grammar()
                        ))
                    }
                },
                None,
            ),
            Some(obj @ Json::Obj(_)) => {
                (d.engine.pipeline, Some(parse_pipeline_spec(obj)?))
            }
            Some(other) => {
                return err(format!(
                    "engine.pipeline: expected a kind name or an ops spec, got {other:?}\n{}",
                    pipeline_grammar()
                ))
            }
        };
        let engine = EngineSection {
            framework: match get_str(&e, "framework", "flink").as_str() {
                "flink" => Framework::Flink,
                "spark" => Framework::Spark,
                "kstreams" | "kafka-streams" => Framework::KStreams,
                other => return err(format!("engine.framework: unknown '{other}'")),
            },
            pipeline,
            pipeline_spec,
            parallelism: get_u64(&e, "parallelism", d.engine.parallelism as u64)? as u32,
            batch_size: get_u64(&e, "batch_size", d.engine.batch_size as u64)? as usize,
            window_micros: get_duration(&e, "window", d.engine.window_micros)?,
            slide_micros: get_duration(&e, "slide", d.engine.slide_micros)?,
            threshold_f: get_f64(&e, "threshold_f", d.engine.threshold_f as f64)? as f32,
            use_hlo: get_bool(&e, "use_hlo", d.engine.use_hlo)?,
            microbatch_micros: get_duration(&e, "microbatch", d.engine.microbatch_micros)?,
            exchange: {
                let name = get_str(&e, "exchange", d.engine.exchange.name());
                ExchangeMode::from_name(&name).ok_or_else(|| {
                    ConfigError(format!(
                        "engine.exchange: unknown mode '{name}' — expected hash or none"
                    ))
                })?
            },
        };

        let m = section(root, "metrics");
        let metrics = MetricsSection {
            sample_interval_micros: get_duration(
                &m,
                "sample_interval",
                d.metrics.sample_interval_micros,
            )?,
            out_dir: get_str(&m, "out_dir", &d.metrics.out_dir),
            egest_dump: get_str(&m, "egest_dump", &d.metrics.egest_dump),
        };

        let c = section(root, "checkpoint");
        let checkpoint = CheckpointSection {
            interval_micros: get_duration(&c, "interval", d.checkpoint.interval_micros)?,
            dir: get_str(&c, "dir", &d.checkpoint.dir),
            retain: get_u64(&c, "retain", d.checkpoint.retain as u64)? as usize,
        };

        let f = section(root, "fault");
        let schedule = match f.get("schedule") {
            None | Some(Json::Null) => Vec::new(),
            Some(Json::Arr(entries)) => entries
                .iter()
                .enumerate()
                .map(|(i, entry)| parse_fault(i, entry))
                .collect::<Result<Vec<_>, _>>()?,
            Some(other) => {
                return err(format!(
                    "fault.schedule: expected a list of faults, got {other:?}\n{}",
                    fault_grammar()
                ))
            }
        };
        let fault = FaultSection {
            kill_task: get_u32(&f, "kill_task", d.fault.kill_task)?,
            kill_after_micros: get_duration(&f, "kill_after", d.fault.kill_after_micros)?,
            schedule,
            restore: get_bool(&f, "restore", d.fault.restore)?,
            heartbeat_timeout_micros: get_duration(
                &f,
                "heartbeat_timeout",
                d.fault.heartbeat_timeout_micros,
            )?,
            max_restarts: get_u32(&f, "max_restarts", d.fault.max_restarts)?,
            backoff_micros: get_duration(&f, "backoff", d.fault.backoff_micros)?,
        };

        let x = section(root, "experiment");
        let experiment = ExperimentSection {
            start_rate: get_u64(&x, "start_rate", d.experiment.start_rate)?,
            step_factor: get_f64(&x, "step_factor", d.experiment.step_factor)?,
            max_iterations: get_u32(&x, "max_iterations", d.experiment.max_iterations)?,
            refine_steps: get_u32(&x, "refine_steps", d.experiment.refine_steps)?,
            sustain_ratio: get_f64(&x, "sustain_ratio", d.experiment.sustain_ratio)?,
            max_p99_micros: get_duration(&x, "max_p99", d.experiment.max_p99_micros)?,
            max_latency_growth: get_f64(
                &x,
                "max_latency_growth",
                d.experiment.max_latency_growth,
            )?,
            iteration_duration_micros: get_duration(
                &x,
                "iteration_duration",
                d.experiment.iteration_duration_micros,
            )?,
            warmup_discard_micros: get_duration(
                &x,
                "warmup_discard",
                d.experiment.warmup_discard_micros,
            )?,
            max_late_fraction: get_f64(&x, "max_late_fraction", d.experiment.max_late_fraction)?,
            max_restarts: get_u32(&x, "max_restarts", d.experiment.max_restarts)?,
            min_availability: get_f64(&x, "min_availability", d.experiment.min_availability)?,
        };

        let s = section(root, "slurm");
        let slurm = SlurmSection {
            enabled: get_bool(&s, "enabled", d.slurm.enabled)?,
            nodes: get_u64(&s, "nodes", d.slurm.nodes as u64)? as u32,
            cpus_per_task: get_u64(&s, "cpus_per_task", d.slurm.cpus_per_task as u64)? as u32,
            mem_bytes: get_bytes(&s, "mem", d.slurm.mem_bytes)?,
            time_limit_micros: get_duration(&s, "time_limit", d.slurm.time_limit_micros)?,
            partition: get_str(&s, "partition", &d.slurm.partition),
        };

        let cl = section(root, "cluster");
        let cluster = ClusterSection {
            transport: {
                let name = get_str(&cl, "transport", d.cluster.transport.name());
                TransportMode::from_name(&name).ok_or_else(|| {
                    ConfigError(format!(
                        "cluster.transport: unknown mode '{name}' — expected local or tcp"
                    ))
                })?
            },
            driver_bind: get_str(&cl, "driver_bind", &d.cluster.driver_bind),
            data_bind: get_str(&cl, "data_bind", &d.cluster.data_bind),
            generators: get_u32(&cl, "generators", d.cluster.generators)?,
            spawn_workers: get_bool(&cl, "spawn_workers", d.cluster.spawn_workers)?,
            connect_timeout_micros: get_duration(
                &cl,
                "connect_timeout",
                d.cluster.connect_timeout_micros,
            )?,
            ready_timeout_micros: get_duration(
                &cl,
                "ready_timeout",
                d.cluster.ready_timeout_micros,
            )?,
        };

        let cfg = Self {
            bench,
            workload,
            generators,
            broker,
            engine,
            metrics,
            checkpoint,
            fault,
            experiment,
            slurm,
            cluster,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation. Called by `from_json`; public for tests.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.workload.event_bytes < 27 {
            return err(format!(
                "workload.event_bytes: minimum event size is 27 bytes (got {})",
                self.workload.event_bytes
            ));
        }
        if self.workload.rate == 0 {
            return err("workload.rate must be > 0");
        }
        if self.workload.sensors == 0 {
            return err("workload.sensors must be > 0");
        }
        if self.broker.partitions == 0 {
            return err("broker.partitions must be > 0");
        }
        if self.engine.parallelism == 0 {
            return err("engine.parallelism must be > 0");
        }
        if self.engine.batch_size == 0 {
            return err("engine.batch_size must be > 0");
        }
        if self.generators.instance_capacity == 0 {
            return err("generators.instance_capacity must be > 0");
        }
        if self.workload.pattern == Pattern::Random
            && self.workload.random.min_rate > self.workload.random.max_rate
        {
            return err("workload.random: min_rate > max_rate");
        }
        if self.workload.pattern == Pattern::Random
            && self.workload.random.min_pause_micros > self.workload.random.max_pause_micros
        {
            return err("workload.random: min_pause > max_pause");
        }
        if self.engine.slide_micros > self.engine.window_micros {
            return err("engine.slide must be <= engine.window");
        }
        // Validate the chain that will actually run: the explicit spec, or
        // the canonical chain of the configured kind (whose window inherits
        // engine.window/slide — so a non-divisible pane spec is caught here
        // for every pipeline, not only explicit `ops:` documents).
        self.validate_spec(&self.engine.effective_spec())?;
        let hot = self.workload.hot_fraction;
        if !(0.0..=1.0).contains(&hot) || !hot.is_finite() {
            return err(format!(
                "workload.hot_fraction must be in [0, 1] (got {hot})"
            ));
        }
        if hot > 0.0 && self.workload.hot_keys == 0 {
            return err("workload.hot_fraction > 0 needs `hot_keys:` > 0 (the hot-set size)");
        }
        if self.workload.hot_keys > self.workload.sensors {
            return err(format!(
                "workload.hot_keys ({}) cannot exceed workload.sensors ({})",
                self.workload.hot_keys, self.workload.sensors
            ));
        }
        let dis = &self.workload.disorder;
        for (name, frac) in [
            ("late_fraction", dis.late_fraction),
            ("straggler_fraction", dis.straggler_fraction),
        ] {
            if !(0.0..=1.0).contains(&frac) || !frac.is_finite() {
                return err(format!(
                    "workload.disorder.{name} must be in [0, 1] (got {frac})"
                ));
            }
        }
        if dis.late_fraction + dis.straggler_fraction > 1.0 {
            return err(format!(
                "workload.disorder: late_fraction + straggler_fraction must not exceed 1 \
                 (got {} + {})",
                dis.late_fraction, dis.straggler_fraction
            ));
        }
        if dis.late_fraction > 0.0 && dis.lateness_micros == 0 {
            return err(
                "workload.disorder.late_fraction > 0 needs `lateness:` > 0 (the delay bound)",
            );
        }
        if dis.straggler_fraction > 0.0 && dis.straggler_micros == 0 {
            return err(
                "workload.disorder.straggler_fraction > 0 needs `straggler_lateness:` > 0 \
                 (the extra delay span beyond `lateness`)",
            );
        }
        // Negated comparisons so NaN (parseable from YAML "nan") fails
        // every bound instead of slipping past it.
        if !(self.experiment.step_factor > 1.0 && self.experiment.step_factor.is_finite()) {
            return err(format!(
                "experiment.step_factor must be a finite number > 1 (got {})",
                self.experiment.step_factor
            ));
        }
        if !(self.experiment.sustain_ratio > 0.0 && self.experiment.sustain_ratio <= 1.0) {
            return err(format!(
                "experiment.sustain_ratio must be in (0, 1] (got {})",
                self.experiment.sustain_ratio
            ));
        }
        if self.experiment.max_iterations == 0 {
            return err("experiment.max_iterations must be > 0");
        }
        let growth = self.experiment.max_latency_growth;
        if !(growth == 0.0 || (growth >= 1.0 && growth.is_finite())) {
            return err(format!(
                "experiment.max_latency_growth must be 0 (disabled) or a finite number >= 1 (got {growth})"
            ));
        }
        let late = self.experiment.max_late_fraction;
        if !(0.0..=1.0).contains(&late) || !late.is_finite() {
            return err(format!(
                "experiment.max_late_fraction must be in [0, 1] (0 disables; got {late})"
            ));
        }
        // Aligned checkpoints quiesce the whole fleet at a consistent
        // epoch; the wall-clock threaded engine can only do that for flat
        // chains, where every task is independent.  Exchange-staged chains
        // checkpoint on the deterministic lockstep harness instead.
        if self.checkpoint.enabled()
            && self.bench.mode == ExecMode::Wall
            && self.engine.exchange == ExchangeMode::Hash
            && self
                .engine
                .effective_spec()
                .split_stages(self.engine.parallelism)
                .len()
                > 1
        {
            return err(
                "checkpoint.interval: wall-mode checkpointing supports flat (single-stage) \
                 chains only; exchange-staged chains snapshot/restore on the deterministic \
                 lockstep harness (LockstepExchange).  Use a spec without `keyby`, or set \
                 `engine.exchange: none`",
            );
        }
        if self.fault.enabled() {
            for fault in self.fault.plan() {
                match fault.kind {
                    FaultKind::KillTask { task } | FaultKind::HangTask { task } => {
                        if task >= self.engine.parallelism {
                            return err(format!(
                                "fault.{} {} is out of range: engine.parallelism is {} \
                                 (task ids are 0-based)",
                                fault.kind.name(),
                                task,
                                self.engine.parallelism
                            ));
                        }
                    }
                    FaultKind::StallPartition { partition } => {
                        if partition >= self.broker.partitions {
                            return err(format!(
                                "fault.stall_partition {} is out of range: broker.partitions \
                                 is {} (partition ids are 0-based)",
                                partition, self.broker.partitions
                            ));
                        }
                    }
                    FaultKind::PoisonRecords { .. } => {}
                    // Detection-only (emitted by the link supervisor);
                    // never appears in a parsed schedule.
                    FaultKind::PeerDisconnect { .. } => {}
                }
            }
            if self.fault.has_restart_faults() {
                if self.fault.restore && !self.checkpoint.enabled() {
                    return err(
                        "fault.restore needs `checkpoint.interval:` > 0 — with checkpointing \
                         disabled there is nothing to restore from; enable checkpointing or set \
                         `fault.restore: false` for a cold restart",
                    );
                }
                if self.fault.heartbeat_timeout_micros == 0 {
                    return err(
                        "fault.heartbeat_timeout must be > 0: the watchdog needs a deadline \
                         to declare a task hung",
                    );
                }
            }
        }
        let avail = self.experiment.min_availability;
        if !(0.0..=1.0).contains(&avail) || !avail.is_finite() {
            return err(format!(
                "experiment.min_availability must be in [0, 1] (0 disables; got {avail})"
            ));
        }
        let needed =
            (self.workload.rate + self.generators.instance_capacity - 1) / self.generators.instance_capacity;
        if needed > self.generators.max_instances as u64 {
            return err(format!(
                "workload.rate {} requires {} generator instances (capacity {}), but generators.max_instances is {}",
                self.workload.rate, needed, self.generators.instance_capacity, self.generators.max_instances
            ));
        }
        if self.cluster.transport == TransportMode::Tcp {
            if self.bench.mode != ExecMode::Wall {
                return err("cluster.transport: tcp needs `benchmark.mode: wall` — sim runs are single-process by construction");
            }
            if self.fault.enabled() {
                return err(
                    "cluster.transport: tcp does not support a fault schedule yet — \
                     distributed runs detect real peer disconnects instead (remove \
                     `fault.schedule`/`kill_after`, or use `transport: local`)",
                );
            }
            if self.checkpoint.enabled() {
                return err(
                    "cluster.transport: tcp does not support checkpointing yet — \
                     disable `checkpoint.interval` or use `transport: local`",
                );
            }
            if self.cluster.connect_timeout_micros == 0
                || self.cluster.connect_timeout_micros > 30_000_000
            {
                return err(format!(
                    "cluster.connect_timeout must be in (0, 30s] so a missing peer fails \
                     loudly (got {}µs)",
                    self.cluster.connect_timeout_micros
                ));
            }
            if self.cluster.ready_timeout_micros == 0 {
                return err("cluster.ready_timeout must be > 0");
            }
            // Externally launched workers (SLURM srun steps) dial a
            // known address, so the driver cannot bind an ephemeral port.
            let driver_port = self
                .cluster
                .driver_bind
                .rsplit(':')
                .next()
                .and_then(|p| p.parse::<u16>().ok())
                .unwrap_or(0);
            if !self.cluster.spawn_workers && driver_port == 0 {
                return err(
                    "cluster.driver_bind must pin a port (e.g. 0.0.0.0:7700) when \
                     spawn_workers is off — externally launched workers must know \
                     where to dial",
                );
            }
        }
        Ok(())
    }

    /// Chain-level validation of an operator spec (per-op parameter bounds
    /// are enforced at parse time; this checks cross-op structure).
    fn validate_spec(&self, spec: &PipelineSpec) -> Result<(), ConfigError> {
        if spec.ops.is_empty() {
            return err(format!("engine.pipeline.ops is empty\n{}", pipeline_grammar()));
        }
        if spec.ops.iter().any(|o| matches!(o, OpSpec::Forward)) && spec.ops.len() > 1 {
            return err(
                "engine.pipeline.ops: `forward` moves raw broker records and must be \
                 the only operator in its chain",
            );
        }
        let mut saw_window = false;
        for (i, op) in spec.ops.iter().enumerate() {
            match op {
                // keyby/topk zero parameters are rejected at YAML parse
                // time, but a programmatically constructed spec skips that
                // layer and would otherwise abort the engine thread on the
                // constructor `assert!` backstops (operator.rs).  Catch
                // them here with the grammar attached.
                OpSpec::KeyBy { modulo: 0, .. } => {
                    return err(format!(
                        "engine.pipeline.ops[{i}] (keyby): needs `modulo:` > 0 — keying by \
                         zero groups is undefined\n{}",
                        pipeline_grammar()
                    ));
                }
                OpSpec::TopK { k: 0, .. } => {
                    return err(format!(
                        "engine.pipeline.ops[{i}] (topk): needs `k:` > 0 — an empty \
                         selection would drop every window\n{}",
                        pipeline_grammar()
                    ));
                }
                OpSpec::KeyBy { parallelism, .. } | OpSpec::TopK { parallelism, .. }
                    if *parallelism > self.engine.parallelism =>
                {
                    return err(format!(
                        "engine.pipeline.ops[{i}] ({}): stage parallelism {} exceeds \
                         engine.parallelism {} — a stage cannot have more instances than \
                         there are task slots to host them",
                        op.op_name(),
                        parallelism,
                        self.engine.parallelism
                    ));
                }
                OpSpec::TopK { parallelism, .. } if *parallelism > 1 => {
                    return err(format!(
                        "engine.pipeline.ops[{i}] (topk): parallelism {parallelism} would \
                         select top-k over each instance's key subset, not globally — \
                         partial top-k is not supported (use 1, or omit for the global \
                         default)"
                    ));
                }
                OpSpec::Window {
                    window_micros,
                    slide_micros,
                    ..
                } => {
                    let w = if *window_micros > 0 {
                        *window_micros
                    } else {
                        self.engine.window_micros
                    };
                    let s = if *slide_micros > 0 {
                        *slide_micros
                    } else {
                        self.engine.slide_micros
                    };
                    if s == 0 || s > w {
                        return err(format!(
                            "engine.pipeline.ops[{i}] (window): needs slide in (0, window] \
                             (resolved window={w}µs slide={s}µs)"
                        ));
                    }
                    // Pane decomposition needs S | W; anything else would
                    // silently truncate W/S panes inside the window state.
                    if w % s != 0 {
                        return err(format!(
                            "engine.pipeline.ops[{i}] (window): slide must divide window \
                             exactly — the window is covered by W/S whole panes \
                             (resolved window={w}µs slide={s}µs leaves a {}µs remainder)\n{}",
                            w % s,
                            pipeline_grammar()
                        ));
                    }
                    saw_window = true;
                }
                OpSpec::TopK { .. } if !saw_window => {
                    return err(format!(
                        "engine.pipeline.ops[{i}] (topk): requires a window(...) earlier in \
                         the chain — top-k selects among window aggregates"
                    ));
                }
                OpSpec::EmitAggregates if !saw_window => {
                    return err(format!(
                        "engine.pipeline.ops[{i}] (emit: aggregates): requires a window(...) \
                         earlier in the chain"
                    ));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// The directory checkpoint files live in: `checkpoint.dir` when set,
    /// else `checkpoints/` under `metrics.out_dir`.
    pub fn checkpoint_dir(&self) -> String {
        if self.checkpoint.dir.is_empty() {
            format!("{}/checkpoints", self.metrics.out_dir)
        } else {
            self.checkpoint.dir.clone()
        }
    }

    /// Number of generator instances auto-scaled from the requested load
    /// (paper Sec. 3.2: "automatically adjusts the number of generators").
    pub fn generator_instances(&self) -> u32 {
        ((self.workload.rate + self.generators.instance_capacity - 1)
            / self.generators.instance_capacity) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::yaml;

    #[test]
    fn defaults_validate() {
        BenchConfig::default().validate().unwrap();
    }

    #[test]
    fn from_empty_json_is_default_like() {
        let cfg = BenchConfig::from_json(&Json::obj()).unwrap();
        assert_eq!(cfg.workload.event_bytes, 27);
        assert_eq!(cfg.engine.parallelism, 4);
        assert_eq!(cfg.bench.mode, ExecMode::Wall);
    }

    #[test]
    fn full_yaml_roundtrip() {
        let y = "
benchmark:
  name: exp1
  seed: 7
  mode: sim
  duration: 30s
workload:
  pattern: burst
  rate: 8M
  event_bytes: 64B
  sensors: 2048
  burst:
    interval: 500ms
    burst_rate: 2M
engine:
  framework: spark
  pipeline: mem
  parallelism: 16
  batch_size: 4096
slurm:
  enabled: true
  nodes: 4
  mem: 200GB
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert_eq!(cfg.bench.name, "exp1");
        assert_eq!(cfg.bench.mode, ExecMode::Sim);
        assert_eq!(cfg.bench.duration_micros, 30_000_000);
        assert_eq!(cfg.workload.pattern, Pattern::Burst);
        assert_eq!(cfg.workload.rate, 8_000_000);
        assert_eq!(cfg.workload.event_bytes, 64);
        assert_eq!(cfg.workload.burst.interval_micros, 500_000);
        assert_eq!(cfg.engine.framework, Framework::Spark);
        assert_eq!(cfg.engine.pipeline, PipelineKind::MemIntensive);
        assert_eq!(cfg.engine.parallelism, 16);
        assert!(cfg.slurm.enabled);
        assert_eq!(cfg.slurm.mem_bytes, 200_000_000_000);
    }

    #[test]
    fn event_size_minimum_enforced() {
        let y = "workload:\n  event_bytes: 20\n";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("27 bytes"), "{e}");
    }

    #[test]
    fn unknown_enum_rejected() {
        let y = "engine:\n  framework: storm\n";
        assert!(BenchConfig::from_json(&yaml::parse(y).unwrap()).is_err());
    }

    #[test]
    fn generator_autoscaling() {
        let mut cfg = BenchConfig::default();
        cfg.workload.rate = 2_000_000;
        cfg.generators.instance_capacity = 500_000;
        assert_eq!(cfg.generator_instances(), 4);
        cfg.workload.rate = 2_000_001;
        assert_eq!(cfg.generator_instances(), 5);
    }

    #[test]
    fn random_pattern_bounds_checked() {
        let y = "
workload:
  pattern: random
  random:
    min_rate: 2M
    max_rate: 1M
";
        assert!(BenchConfig::from_json(&yaml::parse(y).unwrap()).is_err());
    }

    #[test]
    fn slide_greater_than_window_rejected() {
        let y = "engine:\n  window: 5s\n  slide: 10s\n";
        assert!(BenchConfig::from_json(&yaml::parse(y).unwrap()).is_err());
    }

    #[test]
    fn experiment_section_parses_with_units() {
        let y = "
experiment:
  start_rate: 250K
  step_factor: 1.5
  max_iterations: 12
  refine_steps: 6
  sustain_ratio: 0.9
  max_p99: 500ms
  max_latency_growth: 2.5
  iteration_duration: 5s
  warmup_discard: 1s
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert_eq!(cfg.experiment.start_rate, 250_000);
        assert_eq!(cfg.experiment.step_factor, 1.5);
        assert_eq!(cfg.experiment.max_iterations, 12);
        assert_eq!(cfg.experiment.refine_steps, 6);
        assert_eq!(cfg.experiment.sustain_ratio, 0.9);
        assert_eq!(cfg.experiment.max_p99_micros, 500_000);
        assert_eq!(cfg.experiment.max_latency_growth, 2.5);
        assert_eq!(cfg.experiment.iteration_duration_micros, 5_000_000);
        assert_eq!(cfg.experiment.warmup_discard_micros, 1_000_000);
    }

    #[test]
    fn experiment_defaults_are_inherit_markers() {
        let cfg = BenchConfig::from_json(&Json::obj()).unwrap();
        assert_eq!(cfg.experiment.start_rate, 0);
        assert_eq!(cfg.experiment.step_factor, 2.0);
        assert_eq!(cfg.experiment.max_p99_micros, 0);
        assert_eq!(cfg.experiment.iteration_duration_micros, 0);
    }

    #[test]
    fn operator_chain_spec_parses_from_yaml() {
        let y = "
engine:
  pipeline:
    ops:
      - filter:
          cmp: gt
          value: 26.0
      - keyby:
          modulo: 64
      - window:
          agg: mean
          window: 2s
          slide: 1s
      - topk:
          k: 10
      - emit: aggregates
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        let spec = cfg.engine.pipeline_spec.expect("spec parsed");
        assert_eq!(spec.ops.len(), 5);
        assert_eq!(spec.ops[0], OpSpec::Filter { cmp: CmpOp::Gt, value: 26.0 });
        assert_eq!(
            spec.ops[1],
            OpSpec::KeyBy {
                modulo: 64,
                parallelism: 0
            }
        );
        assert_eq!(
            spec.ops[2],
            OpSpec::window(AggKind::Mean, 2_000_000, 1_000_000)
        );
        assert_eq!(
            spec.ops[3],
            OpSpec::TopK {
                k: 10,
                parallelism: 0
            }
        );
        assert_eq!(spec.ops[4], OpSpec::EmitAggregates);
        assert_eq!(
            spec.label(),
            "chain[filter→keyby→window→topk→emit_aggregates]"
        );
    }

    #[test]
    fn flattened_yaml_op_form_is_tolerated() {
        // Two-space continuation puts params beside the op key; the parser
        // must still identify `filter` as the operator.
        let y = "
engine:
  pipeline:
    ops:
      - filter:
        cmp: lt
        value: 5.0
      - emit: events
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        let spec = cfg.engine.pipeline_spec.unwrap();
        assert_eq!(spec.ops[0], OpSpec::Filter { cmp: CmpOp::Lt, value: 5.0 });
        assert_eq!(spec.ops[1], OpSpec::EmitEvents);
    }

    #[test]
    fn unknown_pipeline_kind_error_lists_kinds_and_grammar() {
        let y = "engine:\n  pipeline: storm\n";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("unknown kind 'storm'"), "{e}");
        assert!(e.0.contains("passthrough | cpu | mem | fused"), "{e}");
        assert!(e.0.contains("ops:"), "error must show the spec grammar: {e}");
        assert!(e.0.contains("OperatorRegistry"), "{e}");
    }

    #[test]
    fn bad_spec_params_are_readable_errors() {
        for (y, needle) in [
            (
                "engine:\n  pipeline:\n    ops:\n      - filter:\n          cmp: spaceship\n          value: 1\n",
                "unknown cmp",
            ),
            (
                "engine:\n  pipeline:\n    ops:\n      - window:\n          agg: median\n",
                "unknown agg",
            ),
            ("engine:\n  pipeline:\n    ops:\n      - topk:\n          k: 0\n", "k:"),
            ("engine:\n  pipeline:\n    ops: []\n", "empty"),
            ("engine:\n  pipeline:\n    knobs: 3\n", "ops:"),
        ] {
            let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
            assert!(e.0.contains(needle), "expected '{needle}' in: {e}");
        }
    }

    #[test]
    fn spec_structure_is_validated() {
        // topk before any window.
        let y = "engine:\n  pipeline:\n    ops:\n      - topk:\n          k: 3\n";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("requires a window"), "{e}");
        // forward mixed with other ops.
        let y = "engine:\n  pipeline:\n    ops:\n      - forward\n      - emit: events\n";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("forward"), "{e}");
        // emit aggregates with no window.
        let y = "engine:\n  pipeline:\n    ops:\n      - emit: aggregates\n";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("requires a window"), "{e}");
    }

    #[test]
    fn unknown_op_names_become_custom_specs() {
        let y = "
engine:
  pipeline:
    ops:
      - alert_filter:
          threshold_c: 30.0
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        let spec = cfg.engine.pipeline_spec.unwrap();
        match &spec.ops[0] {
            OpSpec::Custom { name, params } => {
                assert_eq!(name, "alert_filter");
                assert_eq!(params.get("threshold_c").and_then(|v| v.as_f64()), Some(30.0));
            }
            other => panic!("expected custom op, got {other:?}"),
        }
    }

    #[test]
    fn canonical_specs_cover_the_paper_pipelines() {
        assert_eq!(
            PipelineKind::PassThrough.canonical_spec().ops,
            vec![OpSpec::Forward]
        );
        assert_eq!(
            PipelineKind::CpuIntensive.canonical_spec().ops,
            vec![OpSpec::CpuTransform, OpSpec::EmitEvents]
        );
        assert!(PipelineKind::MemIntensive.canonical_spec().has_window());
        assert_eq!(PipelineKind::Fused.canonical_spec().ops.len(), 4);
        // Canonical chains must themselves validate against the defaults.
        for kind in [
            PipelineKind::PassThrough,
            PipelineKind::CpuIntensive,
            PipelineKind::MemIntensive,
            PipelineKind::Fused,
        ] {
            let mut cfg = BenchConfig::default();
            cfg.engine.pipeline_spec = Some(kind.canonical_spec());
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn pipeline_label_reflects_spec_or_kind() {
        let mut cfg = BenchConfig::default();
        assert_eq!(cfg.engine.pipeline_label(), "cpu");
        cfg.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![OpSpec::Forward],
        });
        assert_eq!(cfg.engine.pipeline_label(), "chain[forward]");
    }

    #[test]
    fn disorder_section_parses_with_units() {
        let y = "
workload:
  disorder:
    lateness: 250ms
    late_fraction: 0.25
    straggler_fraction: 0.01
    straggler_lateness: 2s
    shuffle_window: 128
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        let d = &cfg.workload.disorder;
        assert_eq!(d.lateness_micros, 250_000);
        assert_eq!(d.late_fraction, 0.25);
        assert_eq!(d.straggler_fraction, 0.01);
        assert_eq!(d.straggler_micros, 2_000_000);
        assert_eq!(d.shuffle_window, 128);
        assert!(d.enabled());
        assert!(!BenchConfig::default().workload.disorder.enabled());
    }

    #[test]
    fn disorder_bounds_rejected() {
        for (y, needle) in [
            ("workload:\n  disorder:\n    late_fraction: 1.5\n", "late_fraction"),
            ("workload:\n  disorder:\n    straggler_fraction: -0.1\n", "straggler_fraction"),
            (
                "workload:\n  disorder:\n    lateness: 1s\n    late_fraction: 0.6\n    straggler_fraction: 0.6\n    straggler_lateness: 1s\n",
                "must not exceed 1",
            ),
            ("workload:\n  disorder:\n    late_fraction: 0.5\n", "lateness"),
            (
                "workload:\n  disorder:\n    straggler_fraction: 0.1\n",
                "straggler_lateness",
            ),
        ] {
            let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
            assert!(e.0.contains(needle), "expected '{needle}' in: {e}");
        }
    }

    #[test]
    fn event_time_window_spec_parses() {
        let y = "
engine:
  pipeline:
    ops:
      - window:
          agg: mean
          window: 2s
          slide: 1s
          time: event
          allowed_lateness: 250ms
          late_policy: merge_if_open
          watermark: 300ms
      - emit: aggregates
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        let spec = cfg.engine.pipeline_spec.unwrap();
        assert_eq!(
            spec.ops[0],
            OpSpec::Window {
                agg: AggKind::Mean,
                window_micros: 2_000_000,
                slide_micros: 1_000_000,
                time: WindowTime::Event,
                allowed_lateness_micros: 250_000,
                late_policy: LatePolicy::MergeIfOpen,
                watermark_micros: 300_000,
            }
        );
    }

    #[test]
    fn event_time_knobs_rejected_on_processing_windows() {
        let y = "
engine:
  pipeline:
    ops:
      - window:
          agg: mean
          window: 2s
          slide: 1s
          allowed_lateness: 250ms
      - emit: aggregates
";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("time: event"), "{e}");
        // Unknown enum values are readable errors.
        for (y, needle) in [
            (
                "engine:\n  pipeline:\n    ops:\n      - window:\n          time: lunar\n",
                "unknown time",
            ),
            (
                "engine:\n  pipeline:\n    ops:\n      - window:\n          time: event\n          late_policy: hope\n",
                "unknown late_policy",
            ),
        ] {
            let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
            assert!(e.0.contains(needle), "expected '{needle}' in: {e}");
        }
    }

    #[test]
    fn non_divisible_window_slide_rejected_with_grammar() {
        // Explicit spec.
        let y = "
engine:
  pipeline:
    ops:
      - window:
          agg: mean
          window: 10s
          slide: 3s
      - emit: aggregates
";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("slide must divide window"), "{e}");
        assert!(e.0.contains("1000000µs remainder"), "{e}");
        assert!(e.0.contains("ops:"), "error must carry the grammar: {e}");
        // Canonical kind inheriting non-divisible engine.window/slide is
        // caught too (the mem pipeline would silently truncate panes).
        let y = "engine:\n  pipeline: mem\n  window: 10s\n  slide: 3s\n";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("slide must divide window"), "{e}");
    }

    #[test]
    fn max_late_fraction_parses_and_bounds() {
        let y = "experiment:\n  max_late_fraction: 0.05\n";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert_eq!(cfg.experiment.max_late_fraction, 0.05);
        assert_eq!(BenchConfig::default().experiment.max_late_fraction, 0.0);
        for y in [
            "experiment:\n  max_late_fraction: 1.5\n",
            "experiment:\n  max_late_fraction: -0.2\n",
            "experiment:\n  max_late_fraction: nan\n",
        ] {
            assert!(
                BenchConfig::from_json(&yaml::parse(y).unwrap()).is_err(),
                "should reject: {y}"
            );
        }
    }

    #[test]
    fn exchange_mode_parses_and_rejects_unknown() {
        assert_eq!(BenchConfig::default().engine.exchange, ExchangeMode::Hash);
        let y = "engine:\n  exchange: none\n";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert_eq!(cfg.engine.exchange, ExchangeMode::None);
        let y = "engine:\n  exchange: teleport\n";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("hash or none"), "{e}");
    }

    #[test]
    fn per_stage_parallelism_parses_from_yaml() {
        let y = "
engine:
  parallelism: 8
  pipeline:
    ops:
      - keyby:
          modulo: 64
          parallelism: 4
      - window:
          agg: mean
          window: 2s
          slide: 1s
      - topk:
          k: 3
          parallelism: 1
      - emit: aggregates
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        let spec = cfg.engine.pipeline_spec.unwrap();
        assert_eq!(
            spec.ops[0],
            OpSpec::KeyBy {
                modulo: 64,
                parallelism: 4
            }
        );
        assert_eq!(
            spec.ops[2],
            OpSpec::TopK {
                k: 3,
                parallelism: 1
            }
        );
        // Partial top-k (parallelism > 1) would select per key subset —
        // rejected with an explanation.
        let y = y.replace("parallelism: 1", "parallelism: 2");
        let e = BenchConfig::from_json(&yaml::parse(&y).unwrap()).unwrap_err();
        assert!(e.0.contains("partial top-k"), "{e}");
    }

    #[test]
    fn stage_parallelism_beyond_engine_is_rejected() {
        let y = "
engine:
  parallelism: 2
  pipeline:
    ops:
      - keyby:
          modulo: 8
          parallelism: 4
      - window:
          agg: mean
          window: 2s
          slide: 1s
      - emit: aggregates
";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("exceeds"), "{e}");
        assert!(e.0.contains("task slots"), "{e}");
    }

    #[test]
    fn programmatic_keyby_and_topk_zero_rejected_at_validate() {
        // The YAML layer rejects these; a spec built in code must be
        // caught by validate(), not by the engine-thread assert backstop.
        let mut cfg = BenchConfig::default();
        cfg.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![
                OpSpec::KeyBy {
                    modulo: 0,
                    parallelism: 0,
                },
                OpSpec::EmitEvents,
            ],
        });
        let e = cfg.validate().unwrap_err();
        assert!(e.0.contains("modulo"), "{e}");
        assert!(e.0.contains("ops:"), "error must carry the grammar: {e}");
        let mut cfg = BenchConfig::default();
        cfg.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![
                OpSpec::window(AggKind::Mean, 2_000_000, 1_000_000),
                OpSpec::TopK {
                    k: 0,
                    parallelism: 0,
                },
                OpSpec::EmitAggregates,
            ],
        });
        let e = cfg.validate().unwrap_err();
        assert!(e.0.contains("k:"), "{e}");
        assert!(e.0.contains("ops:"), "error must carry the grammar: {e}");
    }

    #[test]
    fn split_stages_cuts_at_keyby_and_topk() {
        let spec = PipelineSpec {
            ops: vec![
                OpSpec::Filter {
                    cmp: CmpOp::Gt,
                    value: 20.0,
                },
                OpSpec::KeyBy {
                    modulo: 64,
                    parallelism: 0,
                },
                OpSpec::window(AggKind::Mean, 1_000_000, 500_000),
                OpSpec::TopK {
                    k: 10,
                    parallelism: 0,
                },
                OpSpec::EmitAggregates,
            ],
        };
        let stages = spec.split_stages(4);
        assert_eq!(stages.len(), 3);
        assert_eq!(stages[0].ops.len(), 2, "filter + keyby");
        assert_eq!(stages[0].parallelism, 4);
        assert_eq!(stages[1].ops.len(), 1, "window");
        assert_eq!(stages[1].parallelism, 4);
        assert_eq!(stages[2].ops.len(), 2, "topk + emit");
        assert_eq!(stages[2].parallelism, 1, "top-k defaults to one global instance");
        // The stage graph is parallelism-independent (instance counts are
        // clamped, the cuts are not).
        let at_one = spec.split_stages(1);
        assert_eq!(at_one.len(), 3);
        assert!(at_one.iter().all(|s| s.parallelism == 1));
        // No keyby → single stage, no exchange.
        let flat = PipelineSpec {
            ops: vec![OpSpec::CpuTransform, OpSpec::EmitEvents],
        };
        assert_eq!(flat.split_stages(4).len(), 1);
        // keyby directly into topk: the opened stage adopts the top-k width.
        let kt = PipelineSpec {
            ops: vec![
                OpSpec::window(AggKind::Mean, 1_000_000, 500_000),
                OpSpec::KeyBy {
                    modulo: 8,
                    parallelism: 0,
                },
                OpSpec::TopK {
                    k: 2,
                    parallelism: 0,
                },
                OpSpec::EmitAggregates,
            ],
        };
        let stages = kt.split_stages(4);
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[1].parallelism, 1, "topk width adopted by the keyby stage");
        assert_eq!(stages[1].ops.len(), 2, "topk + emit share the keyby-opened stage");
    }

    #[test]
    fn hot_key_knobs_parse_and_bound() {
        let y = "workload:\n  sensors: 256\n  hot_keys: 8\n  hot_fraction: 0.5\n";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert_eq!(cfg.workload.hot_keys, 8);
        assert_eq!(cfg.workload.hot_fraction, 0.5);
        for (y, needle) in [
            ("workload:\n  hot_fraction: 1.5\n", "hot_fraction"),
            ("workload:\n  hot_fraction: 0.2\n", "hot_keys"),
            (
                "workload:\n  sensors: 16\n  hot_keys: 64\n  hot_fraction: 0.1\n",
                "cannot exceed",
            ),
        ] {
            let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
            assert!(e.0.contains(needle), "expected '{needle}' in: {e}");
        }
    }

    #[test]
    fn checkpoint_and_fault_sections_parse_with_units() {
        let y = "
checkpoint:
  interval: 500ms
  dir: /tmp/ckpts
  retain: 5
fault:
  kill_task: 2
  kill_after: 2s
  restore: true
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert_eq!(cfg.checkpoint.interval_micros, 500_000);
        assert!(cfg.checkpoint.enabled());
        assert_eq!(cfg.checkpoint.dir, "/tmp/ckpts");
        assert_eq!(cfg.checkpoint.retain, 5);
        assert_eq!(cfg.checkpoint_dir(), "/tmp/ckpts");
        assert_eq!(cfg.fault.kill_task, 2);
        assert_eq!(cfg.fault.kill_after_micros, 2_000_000);
        assert!(cfg.fault.enabled());
        assert!(cfg.fault.restore);
        // Defaults: both disabled, dir derived under metrics.out_dir.
        let d = BenchConfig::default();
        assert!(!d.checkpoint.enabled());
        assert!(!d.fault.enabled());
        assert_eq!(d.checkpoint.retain, 2);
        assert_eq!(d.checkpoint_dir(), "runs/checkpoints");
    }

    #[test]
    fn fault_plan_bounds_are_validated() {
        // kill_task beyond the task-slot range.
        let y = "
engine:
  parallelism: 2
checkpoint:
  interval: 1s
fault:
  kill_task: 2
  kill_after: 1s
";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("kill_task"), "{e}");
        assert!(e.0.contains("parallelism"), "{e}");
        // restore without checkpointing enabled.
        let y = "fault:\n  kill_after: 1s\n";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("checkpoint.interval"), "{e}");
        // ...but an explicit cold restart is fine.
        let y = "fault:\n  kill_after: 1s\n  restore: false\n";
        BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
    }

    #[test]
    fn fault_schedule_parses_all_kinds_with_units() {
        let y = "
checkpoint:
  interval: 200ms
fault:
  heartbeat_timeout: 150ms
  max_restarts: 5
  backoff: 25ms
  schedule:
    - kill_task: 1
      at: 500ms
    - hang_task: 0
      at: 900ms
      duration: 300ms
    - stall_partition: 2
      at: 1s
      duration: 200ms
    - poison_records: 0.05
      seed: 7
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert!(cfg.fault.enabled());
        assert_eq!(cfg.fault.heartbeat_timeout_micros, 150_000);
        assert_eq!(cfg.fault.max_restarts, 5);
        assert_eq!(cfg.fault.backoff_micros, 25_000);
        let plan = cfg.fault.plan();
        assert_eq!(plan.len(), 4);
        // Sorted by injection time: poison (at 0) first.
        assert_eq!(plan[0].kind, FaultKind::PoisonRecords { fraction: 0.05 });
        assert_eq!(plan[0].seed, 7);
        assert_eq!(plan[1].kind, FaultKind::KillTask { task: 1 });
        assert_eq!(plan[1].at_micros, 500_000);
        assert_eq!(plan[2].kind, FaultKind::HangTask { task: 0 });
        assert_eq!(plan[2].duration_micros, 300_000);
        assert_eq!(plan[3].kind, FaultKind::StallPartition { partition: 2 });
        assert!(cfg.fault.has_restart_faults());
        assert_eq!(cfg.fault.poison_plan().len(), 1);
        // The legacy pair merges into the plan as one more kill.
        let mut cfg = cfg;
        cfg.fault.kill_task = 0;
        cfg.fault.kill_after_micros = 100_000;
        assert_eq!(cfg.fault.plan().len(), 5);
        assert_eq!(cfg.fault.plan()[0].kind, FaultKind::PoisonRecords { fraction: 0.05 });
        assert_eq!(cfg.fault.plan()[1].kind, FaultKind::KillTask { task: 0 });
    }

    #[test]
    fn fault_schedule_bounds_are_validated() {
        for (y, needle) in [
            (
                "engine:\n  parallelism: 2\ncheckpoint:\n  interval: 1s\nfault:\n  schedule:\n    - hang_task: 2\n      at: 1s\n      duration: 100ms\n",
                "hang_task 2 is out of range",
            ),
            (
                "broker:\n  partitions: 4\nfault:\n  schedule:\n    - stall_partition: 4\n      at: 1s\n      duration: 100ms\n",
                "stall_partition 4 is out of range",
            ),
            (
                "fault:\n  schedule:\n    - poison_records: 1.5\n",
                "poison_records fraction",
            ),
            (
                "fault:\n  schedule:\n    - hang_task: 0\n      at: 1s\n",
                "duration",
            ),
            (
                "checkpoint:\n  interval: 1s\nfault:\n  schedule:\n    - kill_task: 0\n      at: 1s\n  heartbeat_timeout: 0\n",
                "heartbeat_timeout",
            ),
            (
                "fault:\n  schedule:\n    - kill_task: 0\n      at: 1s\n",
                "checkpoint.interval",
            ),
            (
                "fault:\n  schedule:\n    - flood_disk: 1\n",
                "no fault kind",
            ),
            ("experiment:\n  min_availability: 1.5\n", "min_availability"),
        ] {
            let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
            assert!(e.0.contains(needle), "expected '{needle}' in: {e}");
        }
        // A pure-degradation schedule (stall + poison) needs no checkpoint.
        let y = "fault:\n  schedule:\n    - stall_partition: 0\n      at: 1s\n      duration: 100ms\n    - poison_records: 0.1\n";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert!(!cfg.fault.has_restart_faults());
        // experiment SLO knobs parse.
        let y = "experiment:\n  max_restarts: 2\n  min_availability: 0.99\n";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert_eq!(cfg.experiment.max_restarts, 2);
        assert_eq!(cfg.experiment.min_availability, 0.99);
    }

    #[test]
    fn wall_mode_staged_checkpointing_rejected_readably() {
        let staged = "
checkpoint:
  interval: 1s
engine:
  pipeline:
    ops:
      - keyby:
          modulo: 16
      - window:
          agg: sum
          window: 1s
          slide: 500ms
      - emit: aggregates
";
        let e = BenchConfig::from_json(&yaml::parse(staged).unwrap()).unwrap_err();
        assert!(e.0.contains("lockstep"), "{e}");
        assert!(e.0.contains("flat"), "{e}");
        // Sim mode prices the same config instead of running it.
        let sim = format!("benchmark:\n  mode: sim\n{staged}");
        BenchConfig::from_json(&yaml::parse(&sim).unwrap()).unwrap();
        // Disabling the exchange keeps the chain flat (task-local keyby).
        let mut cfg = BenchConfig::default();
        cfg.checkpoint.interval_micros = 1_000_000;
        cfg.engine.exchange = ExchangeMode::None;
        cfg.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![
                OpSpec::KeyBy {
                    modulo: 16,
                    parallelism: 0,
                },
                OpSpec::window(AggKind::Sum, 1_000_000, 500_000),
                OpSpec::EmitAggregates,
            ],
        });
        cfg.validate().unwrap();
        // A flat chain checkpoints in wall mode without complaint.
        let mut cfg = BenchConfig::default();
        cfg.checkpoint.interval_micros = 1_000_000;
        cfg.validate().unwrap();
    }

    #[test]
    fn experiment_bounds_rejected() {
        for y in [
            "experiment:\n  step_factor: 1.0\n",
            "experiment:\n  step_factor: nan\n",
            "experiment:\n  step_factor: inf\n",
            "experiment:\n  sustain_ratio: 0\n",
            "experiment:\n  sustain_ratio: 1.5\n",
            "experiment:\n  sustain_ratio: nan\n",
            "experiment:\n  max_iterations: 0\n",
            "experiment:\n  max_iterations: 4294967297\n",
            "experiment:\n  refine_steps: 4294967296\n",
            "experiment:\n  max_latency_growth: 0.5\n",
            "experiment:\n  max_latency_growth: nan\n",
        ] {
            assert!(
                BenchConfig::from_json(&yaml::parse(y).unwrap()).is_err(),
                "should reject: {y}"
            );
        }
    }
}
