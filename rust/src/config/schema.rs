//! Typed configuration schema with defaults and validation.
//!
//! One `BenchConfig` drives every component (paper Sec. 3: the master
//! config is the only manual step).  All quantities accept human units
//! ("500K", "27B", "30s") via [`crate::util::units`].

use crate::util::json::Json;
use crate::util::units::{parse_bytes, parse_count, parse_duration_micros};

/// Execution mode: real threads + real time, or discrete-event virtual time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    Wall,
    Sim,
}

/// Workload generation pattern (paper Sec. 3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    Constant,
    Random,
    Burst,
}

/// Stream-processing framework personality (paper Sec. 3: Flink, Spark
/// Streaming and Kafka Streams are fully integrated).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Framework {
    Flink,
    Spark,
    KStreams,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::Flink => "flink",
            Framework::Spark => "spark",
            Framework::KStreams => "kstreams",
        }
    }
}

/// Processing pipeline class (paper Sec. 3.3) plus the fused extension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineKind {
    PassThrough,
    CpuIntensive,
    MemIntensive,
    Fused,
}

impl PipelineKind {
    pub fn name(self) -> &'static str {
        match self {
            PipelineKind::PassThrough => "passthrough",
            PipelineKind::CpuIntensive => "cpu",
            PipelineKind::MemIntensive => "mem",
            PipelineKind::Fused => "fused",
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchSection {
    pub name: String,
    pub seed: u64,
    pub mode: ExecMode,
    pub duration_micros: u64,
    pub warmup_micros: u64,
}

#[derive(Clone, Debug)]
pub struct RandomPattern {
    pub min_rate: u64,
    pub max_rate: u64,
    pub min_pause_micros: u64,
    pub max_pause_micros: u64,
}

#[derive(Clone, Debug)]
pub struct BurstPattern {
    pub interval_micros: u64,
    pub burst_rate: u64,
}

#[derive(Clone, Debug)]
pub struct WorkloadSection {
    pub pattern: Pattern,
    /// Total offered load, events/second, across all generator instances.
    pub rate: u64,
    /// Serialized event size; paper minimum is 27 bytes.
    pub event_bytes: usize,
    /// Number of distinct sensor ids (keyed-state width K).
    pub sensors: u32,
    /// Zipf exponent for key skew; 0 = uniform.
    pub key_skew: f64,
    pub random: RandomPattern,
    pub burst: BurstPattern,
}

#[derive(Clone, Debug)]
pub struct GeneratorSection {
    /// Rated capacity of one generator instance (events/s); the paper's
    /// generator does ~500K ev/s per instance and auto-scales instances.
    pub instance_capacity: u64,
    pub max_instances: u32,
    pub heap_bytes: u64,
}

#[derive(Clone, Debug)]
pub struct BrokerSection {
    pub partitions: u32,
    pub io_threads: u32,
    pub network_threads: u32,
    /// Per-partition bounded queue depth (records) — the backpressure knob.
    pub queue_depth: usize,
    pub heap_bytes: u64,
    /// Simulated per-record broker overhead (wall mode), microseconds.
    pub record_overhead_nanos: u64,
}

#[derive(Clone, Debug)]
pub struct EngineSection {
    pub framework: Framework,
    pub pipeline: PipelineKind,
    pub parallelism: u32,
    pub batch_size: usize,
    pub window_micros: u64,
    pub slide_micros: u64,
    pub threshold_f: f32,
    /// Execute pipeline compute through the AOT HLO artifacts (default) or
    /// through the native Rust reference ops (ablation baseline).
    pub use_hlo: bool,
    /// Micro-batch interval for the Spark personality.
    pub microbatch_micros: u64,
}

#[derive(Clone, Debug)]
pub struct MetricsSection {
    pub sample_interval_micros: u64,
    pub out_dir: String,
}

/// Max-capacity experiment controls (the `experiment:` section).
///
/// Drives [`crate::experiment::MaxCapacityDriver`]: an escalation loop that
/// multiplies the offered load by `step_factor` each iteration until the
/// sustainability predicate fails, then binary-searches the knee for
/// `refine_steps` rounds.  Sustainability follows the stepped-load
/// definition of Karimov et al. / ShuffleBench: the engine keeps up with
/// the offered rate without a growing backlog or runaway latency.
#[derive(Clone, Debug)]
pub struct ExperimentSection {
    /// Initial target rate (events/s) for the escalation loop;
    /// 0 = inherit `workload.rate`.
    pub start_rate: u64,
    /// Multiplicative step applied to the target rate each escalation
    /// round; must be > 1.
    pub step_factor: f64,
    /// Maximum escalation iterations before the sweep gives up looking
    /// for the knee.
    pub max_iterations: u32,
    /// Binary-search refinement rounds once the knee is bracketed.
    pub refine_steps: u32,
    /// A run is sustainable only if `processed_rate >= sustain_ratio *
    /// offered_rate` (and the fleet itself achieved `sustain_ratio` of the
    /// target).
    pub sustain_ratio: f64,
    /// p99 end-to-end latency bound in µs; 0 disables the check.
    pub max_p99_micros: u64,
    /// Bound on latency drift across the run: mean p50 of the second half
    /// of the timeline may be at most this multiple of the first half.
    /// 0 disables; values in (0, 1) are rejected.
    pub max_latency_growth: f64,
    /// Per-iteration measured duration; 0 = inherit `benchmark.duration`.
    pub iteration_duration_micros: u64,
    /// Timeline samples earlier than this offset from the start of each
    /// iteration are discarded before evaluating sustainability;
    /// 0 = inherit `benchmark.warmup`.
    pub warmup_discard_micros: u64,
}

#[derive(Clone, Debug)]
pub struct SlurmSection {
    pub enabled: bool,
    pub nodes: u32,
    pub cpus_per_task: u32,
    pub mem_bytes: u64,
    pub time_limit_micros: u64,
    pub partition: String,
}

/// The master configuration: one file controls every component.
#[derive(Clone, Debug)]
pub struct BenchConfig {
    pub bench: BenchSection,
    pub workload: WorkloadSection,
    pub generators: GeneratorSection,
    pub broker: BrokerSection,
    pub engine: EngineSection,
    pub metrics: MetricsSection,
    pub experiment: ExperimentSection,
    pub slurm: SlurmSection,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            bench: BenchSection {
                name: "bench".into(),
                seed: 42,
                mode: ExecMode::Wall,
                duration_micros: 10_000_000,
                warmup_micros: 1_000_000,
            },
            workload: WorkloadSection {
                pattern: Pattern::Constant,
                rate: 100_000,
                event_bytes: 27,
                sensors: 1024,
                key_skew: 0.0,
                random: RandomPattern {
                    min_rate: 50_000,
                    max_rate: 200_000,
                    min_pause_micros: 1_000,
                    max_pause_micros: 10_000,
                },
                burst: BurstPattern {
                    interval_micros: 1_000_000,
                    burst_rate: 1_000_000,
                },
            },
            generators: GeneratorSection {
                instance_capacity: 500_000,
                max_instances: 64,
                heap_bytes: 2_000_000_000,
            },
            broker: BrokerSection {
                partitions: 4,
                io_threads: 4,
                network_threads: 2,
                queue_depth: 65_536,
                heap_bytes: 5_000_000_000,
                record_overhead_nanos: 0,
            },
            engine: EngineSection {
                framework: Framework::Flink,
                pipeline: PipelineKind::CpuIntensive,
                parallelism: 4,
                batch_size: 1024,
                window_micros: 10_000_000,
                slide_micros: 2_000_000,
                threshold_f: 80.0,
                use_hlo: true,
                microbatch_micros: 100_000,
            },
            metrics: MetricsSection {
                sample_interval_micros: 1_000_000,
                out_dir: "runs".into(),
            },
            experiment: ExperimentSection {
                start_rate: 0,
                step_factor: 2.0,
                max_iterations: 8,
                refine_steps: 4,
                sustain_ratio: 0.95,
                max_p99_micros: 0,
                max_latency_growth: 0.0,
                iteration_duration_micros: 0,
                warmup_discard_micros: 0,
            },
            slurm: SlurmSection {
                enabled: false,
                nodes: 1,
                cpus_per_task: 16,
                mem_bytes: 200_000_000_000,
                time_limit_micros: 1_800_000_000,
                partition: "barnard".into(),
            },
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

fn err<T>(msg: impl Into<String>) -> Result<T, ConfigError> {
    Err(ConfigError(msg.into()))
}

// --- helpers to read Json fields with unit parsing --------------------------

fn get_str(j: &Json, key: &str, default: &str) -> String {
    j.get(key)
        .and_then(|v| v.as_str())
        .unwrap_or(default)
        .to_string()
}

fn get_u64(j: &Json, key: &str, default: u64) -> Result<u64, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Int(i)) if *i >= 0 => Ok(*i as u64),
        Some(Json::Num(f)) if *f >= 0.0 => Ok(*f as u64),
        Some(Json::Str(s)) => parse_count(s).map_err(ConfigError),
        Some(other) => err(format!("field '{key}': expected count, got {other:?}")),
    }
}

fn get_u32(j: &Json, key: &str, default: u32) -> Result<u32, ConfigError> {
    let v = get_u64(j, key, default as u64)?;
    u32::try_from(v).map_err(|_| ConfigError(format!("field '{key}': {v} exceeds u32 range")))
}

fn get_bytes(j: &Json, key: &str, default: u64) -> Result<u64, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Int(i)) if *i >= 0 => Ok(*i as u64),
        Some(Json::Str(s)) => parse_bytes(s).map_err(ConfigError),
        Some(other) => err(format!("field '{key}': expected size, got {other:?}")),
    }
}

fn get_duration(j: &Json, key: &str, default: u64) -> Result<u64, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(Json::Int(i)) if *i >= 0 => Ok(*i as u64 * 1_000_000),
        Some(Json::Num(f)) if *f >= 0.0 => Ok((*f * 1e6) as u64),
        Some(Json::Str(s)) => parse_duration_micros(s).map_err(ConfigError),
        Some(other) => err(format!("field '{key}': expected duration, got {other:?}")),
    }
}

fn get_f64(j: &Json, key: &str, default: f64) -> Result<f64, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| ConfigError(format!("field '{key}': expected number"))),
    }
}

fn get_bool(j: &Json, key: &str, default: bool) -> Result<bool, ConfigError> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| ConfigError(format!("field '{key}': expected bool"))),
    }
}

fn section<'a>(j: &'a Json, key: &str) -> Json {
    j.get(key).cloned().unwrap_or_else(Json::obj)
}

impl BenchConfig {
    /// Build a config from a parsed YAML/JSON tree, applying defaults.
    pub fn from_json(root: &Json) -> Result<Self, ConfigError> {
        let d = BenchConfig::default();

        let b = section(root, "benchmark");
        let bench = BenchSection {
            name: get_str(&b, "name", &d.bench.name),
            seed: get_u64(&b, "seed", d.bench.seed)?,
            mode: match get_str(&b, "mode", "wall").as_str() {
                "wall" => ExecMode::Wall,
                "sim" => ExecMode::Sim,
                other => return err(format!("benchmark.mode: unknown '{other}'")),
            },
            duration_micros: get_duration(&b, "duration", d.bench.duration_micros)?,
            warmup_micros: get_duration(&b, "warmup", d.bench.warmup_micros)?,
        };

        let w = section(root, "workload");
        let rnd = section(&w, "random");
        let burst = section(&w, "burst");
        let workload = WorkloadSection {
            pattern: match get_str(&w, "pattern", "constant").as_str() {
                "constant" => Pattern::Constant,
                "random" => Pattern::Random,
                "burst" => Pattern::Burst,
                other => return err(format!("workload.pattern: unknown '{other}'")),
            },
            rate: get_u64(&w, "rate", d.workload.rate)?,
            event_bytes: get_bytes(&w, "event_bytes", d.workload.event_bytes as u64)? as usize,
            sensors: get_u64(&w, "sensors", d.workload.sensors as u64)? as u32,
            key_skew: get_f64(&w, "key_skew", d.workload.key_skew)?,
            random: RandomPattern {
                min_rate: get_u64(&rnd, "min_rate", d.workload.random.min_rate)?,
                max_rate: get_u64(&rnd, "max_rate", d.workload.random.max_rate)?,
                min_pause_micros: get_duration(
                    &rnd,
                    "min_pause",
                    d.workload.random.min_pause_micros,
                )?,
                max_pause_micros: get_duration(
                    &rnd,
                    "max_pause",
                    d.workload.random.max_pause_micros,
                )?,
            },
            burst: BurstPattern {
                interval_micros: get_duration(&burst, "interval", d.workload.burst.interval_micros)?,
                burst_rate: get_u64(&burst, "burst_rate", d.workload.burst.burst_rate)?,
            },
        };

        let g = section(root, "generators");
        let generators = GeneratorSection {
            instance_capacity: get_u64(&g, "instance_capacity", d.generators.instance_capacity)?,
            max_instances: get_u64(&g, "max_instances", d.generators.max_instances as u64)? as u32,
            heap_bytes: get_bytes(&g, "heap", d.generators.heap_bytes)?,
        };

        let br = section(root, "broker");
        let broker = BrokerSection {
            partitions: get_u64(&br, "partitions", d.broker.partitions as u64)? as u32,
            io_threads: get_u64(&br, "io_threads", d.broker.io_threads as u64)? as u32,
            network_threads: get_u64(&br, "network_threads", d.broker.network_threads as u64)?
                as u32,
            queue_depth: get_u64(&br, "queue_depth", d.broker.queue_depth as u64)? as usize,
            heap_bytes: get_bytes(&br, "heap", d.broker.heap_bytes)?,
            record_overhead_nanos: get_u64(
                &br,
                "record_overhead_nanos",
                d.broker.record_overhead_nanos,
            )?,
        };

        let e = section(root, "engine");
        let engine = EngineSection {
            framework: match get_str(&e, "framework", "flink").as_str() {
                "flink" => Framework::Flink,
                "spark" => Framework::Spark,
                "kstreams" | "kafka-streams" => Framework::KStreams,
                other => return err(format!("engine.framework: unknown '{other}'")),
            },
            pipeline: match get_str(&e, "pipeline", "cpu").as_str() {
                "passthrough" => PipelineKind::PassThrough,
                "cpu" => PipelineKind::CpuIntensive,
                "mem" => PipelineKind::MemIntensive,
                "fused" => PipelineKind::Fused,
                other => return err(format!("engine.pipeline: unknown '{other}'")),
            },
            parallelism: get_u64(&e, "parallelism", d.engine.parallelism as u64)? as u32,
            batch_size: get_u64(&e, "batch_size", d.engine.batch_size as u64)? as usize,
            window_micros: get_duration(&e, "window", d.engine.window_micros)?,
            slide_micros: get_duration(&e, "slide", d.engine.slide_micros)?,
            threshold_f: get_f64(&e, "threshold_f", d.engine.threshold_f as f64)? as f32,
            use_hlo: get_bool(&e, "use_hlo", d.engine.use_hlo)?,
            microbatch_micros: get_duration(&e, "microbatch", d.engine.microbatch_micros)?,
        };

        let m = section(root, "metrics");
        let metrics = MetricsSection {
            sample_interval_micros: get_duration(
                &m,
                "sample_interval",
                d.metrics.sample_interval_micros,
            )?,
            out_dir: get_str(&m, "out_dir", &d.metrics.out_dir),
        };

        let x = section(root, "experiment");
        let experiment = ExperimentSection {
            start_rate: get_u64(&x, "start_rate", d.experiment.start_rate)?,
            step_factor: get_f64(&x, "step_factor", d.experiment.step_factor)?,
            max_iterations: get_u32(&x, "max_iterations", d.experiment.max_iterations)?,
            refine_steps: get_u32(&x, "refine_steps", d.experiment.refine_steps)?,
            sustain_ratio: get_f64(&x, "sustain_ratio", d.experiment.sustain_ratio)?,
            max_p99_micros: get_duration(&x, "max_p99", d.experiment.max_p99_micros)?,
            max_latency_growth: get_f64(
                &x,
                "max_latency_growth",
                d.experiment.max_latency_growth,
            )?,
            iteration_duration_micros: get_duration(
                &x,
                "iteration_duration",
                d.experiment.iteration_duration_micros,
            )?,
            warmup_discard_micros: get_duration(
                &x,
                "warmup_discard",
                d.experiment.warmup_discard_micros,
            )?,
        };

        let s = section(root, "slurm");
        let slurm = SlurmSection {
            enabled: get_bool(&s, "enabled", d.slurm.enabled)?,
            nodes: get_u64(&s, "nodes", d.slurm.nodes as u64)? as u32,
            cpus_per_task: get_u64(&s, "cpus_per_task", d.slurm.cpus_per_task as u64)? as u32,
            mem_bytes: get_bytes(&s, "mem", d.slurm.mem_bytes)?,
            time_limit_micros: get_duration(&s, "time_limit", d.slurm.time_limit_micros)?,
            partition: get_str(&s, "partition", &d.slurm.partition),
        };

        let cfg = Self {
            bench,
            workload,
            generators,
            broker,
            engine,
            metrics,
            experiment,
            slurm,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation. Called by `from_json`; public for tests.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.workload.event_bytes < 27 {
            return err(format!(
                "workload.event_bytes: minimum event size is 27 bytes (got {})",
                self.workload.event_bytes
            ));
        }
        if self.workload.rate == 0 {
            return err("workload.rate must be > 0");
        }
        if self.workload.sensors == 0 {
            return err("workload.sensors must be > 0");
        }
        if self.broker.partitions == 0 {
            return err("broker.partitions must be > 0");
        }
        if self.engine.parallelism == 0 {
            return err("engine.parallelism must be > 0");
        }
        if self.engine.batch_size == 0 {
            return err("engine.batch_size must be > 0");
        }
        if self.generators.instance_capacity == 0 {
            return err("generators.instance_capacity must be > 0");
        }
        if self.workload.pattern == Pattern::Random
            && self.workload.random.min_rate > self.workload.random.max_rate
        {
            return err("workload.random: min_rate > max_rate");
        }
        if self.workload.pattern == Pattern::Random
            && self.workload.random.min_pause_micros > self.workload.random.max_pause_micros
        {
            return err("workload.random: min_pause > max_pause");
        }
        if self.engine.slide_micros > self.engine.window_micros {
            return err("engine.slide must be <= engine.window");
        }
        // Negated comparisons so NaN (parseable from YAML "nan") fails
        // every bound instead of slipping past it.
        if !(self.experiment.step_factor > 1.0 && self.experiment.step_factor.is_finite()) {
            return err(format!(
                "experiment.step_factor must be a finite number > 1 (got {})",
                self.experiment.step_factor
            ));
        }
        if !(self.experiment.sustain_ratio > 0.0 && self.experiment.sustain_ratio <= 1.0) {
            return err(format!(
                "experiment.sustain_ratio must be in (0, 1] (got {})",
                self.experiment.sustain_ratio
            ));
        }
        if self.experiment.max_iterations == 0 {
            return err("experiment.max_iterations must be > 0");
        }
        let growth = self.experiment.max_latency_growth;
        if !(growth == 0.0 || (growth >= 1.0 && growth.is_finite())) {
            return err(format!(
                "experiment.max_latency_growth must be 0 (disabled) or a finite number >= 1 (got {growth})"
            ));
        }
        let needed =
            (self.workload.rate + self.generators.instance_capacity - 1) / self.generators.instance_capacity;
        if needed > self.generators.max_instances as u64 {
            return err(format!(
                "workload.rate {} requires {} generator instances (capacity {}), but generators.max_instances is {}",
                self.workload.rate, needed, self.generators.instance_capacity, self.generators.max_instances
            ));
        }
        Ok(())
    }

    /// Number of generator instances auto-scaled from the requested load
    /// (paper Sec. 3.2: "automatically adjusts the number of generators").
    pub fn generator_instances(&self) -> u32 {
        ((self.workload.rate + self.generators.instance_capacity - 1)
            / self.generators.instance_capacity) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::yaml;

    #[test]
    fn defaults_validate() {
        BenchConfig::default().validate().unwrap();
    }

    #[test]
    fn from_empty_json_is_default_like() {
        let cfg = BenchConfig::from_json(&Json::obj()).unwrap();
        assert_eq!(cfg.workload.event_bytes, 27);
        assert_eq!(cfg.engine.parallelism, 4);
        assert_eq!(cfg.bench.mode, ExecMode::Wall);
    }

    #[test]
    fn full_yaml_roundtrip() {
        let y = "
benchmark:
  name: exp1
  seed: 7
  mode: sim
  duration: 30s
workload:
  pattern: burst
  rate: 8M
  event_bytes: 64B
  sensors: 2048
  burst:
    interval: 500ms
    burst_rate: 2M
engine:
  framework: spark
  pipeline: mem
  parallelism: 16
  batch_size: 4096
slurm:
  enabled: true
  nodes: 4
  mem: 200GB
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert_eq!(cfg.bench.name, "exp1");
        assert_eq!(cfg.bench.mode, ExecMode::Sim);
        assert_eq!(cfg.bench.duration_micros, 30_000_000);
        assert_eq!(cfg.workload.pattern, Pattern::Burst);
        assert_eq!(cfg.workload.rate, 8_000_000);
        assert_eq!(cfg.workload.event_bytes, 64);
        assert_eq!(cfg.workload.burst.interval_micros, 500_000);
        assert_eq!(cfg.engine.framework, Framework::Spark);
        assert_eq!(cfg.engine.pipeline, PipelineKind::MemIntensive);
        assert_eq!(cfg.engine.parallelism, 16);
        assert!(cfg.slurm.enabled);
        assert_eq!(cfg.slurm.mem_bytes, 200_000_000_000);
    }

    #[test]
    fn event_size_minimum_enforced() {
        let y = "workload:\n  event_bytes: 20\n";
        let e = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap_err();
        assert!(e.0.contains("27 bytes"), "{e}");
    }

    #[test]
    fn unknown_enum_rejected() {
        let y = "engine:\n  framework: storm\n";
        assert!(BenchConfig::from_json(&yaml::parse(y).unwrap()).is_err());
    }

    #[test]
    fn generator_autoscaling() {
        let mut cfg = BenchConfig::default();
        cfg.workload.rate = 2_000_000;
        cfg.generators.instance_capacity = 500_000;
        assert_eq!(cfg.generator_instances(), 4);
        cfg.workload.rate = 2_000_001;
        assert_eq!(cfg.generator_instances(), 5);
    }

    #[test]
    fn random_pattern_bounds_checked() {
        let y = "
workload:
  pattern: random
  random:
    min_rate: 2M
    max_rate: 1M
";
        assert!(BenchConfig::from_json(&yaml::parse(y).unwrap()).is_err());
    }

    #[test]
    fn slide_greater_than_window_rejected() {
        let y = "engine:\n  window: 5s\n  slide: 10s\n";
        assert!(BenchConfig::from_json(&yaml::parse(y).unwrap()).is_err());
    }

    #[test]
    fn experiment_section_parses_with_units() {
        let y = "
experiment:
  start_rate: 250K
  step_factor: 1.5
  max_iterations: 12
  refine_steps: 6
  sustain_ratio: 0.9
  max_p99: 500ms
  max_latency_growth: 2.5
  iteration_duration: 5s
  warmup_discard: 1s
";
        let cfg = BenchConfig::from_json(&yaml::parse(y).unwrap()).unwrap();
        assert_eq!(cfg.experiment.start_rate, 250_000);
        assert_eq!(cfg.experiment.step_factor, 1.5);
        assert_eq!(cfg.experiment.max_iterations, 12);
        assert_eq!(cfg.experiment.refine_steps, 6);
        assert_eq!(cfg.experiment.sustain_ratio, 0.9);
        assert_eq!(cfg.experiment.max_p99_micros, 500_000);
        assert_eq!(cfg.experiment.max_latency_growth, 2.5);
        assert_eq!(cfg.experiment.iteration_duration_micros, 5_000_000);
        assert_eq!(cfg.experiment.warmup_discard_micros, 1_000_000);
    }

    #[test]
    fn experiment_defaults_are_inherit_markers() {
        let cfg = BenchConfig::from_json(&Json::obj()).unwrap();
        assert_eq!(cfg.experiment.start_rate, 0);
        assert_eq!(cfg.experiment.step_factor, 2.0);
        assert_eq!(cfg.experiment.max_p99_micros, 0);
        assert_eq!(cfg.experiment.iteration_duration_micros, 0);
    }

    #[test]
    fn experiment_bounds_rejected() {
        for y in [
            "experiment:\n  step_factor: 1.0\n",
            "experiment:\n  step_factor: nan\n",
            "experiment:\n  step_factor: inf\n",
            "experiment:\n  sustain_ratio: 0\n",
            "experiment:\n  sustain_ratio: 1.5\n",
            "experiment:\n  sustain_ratio: nan\n",
            "experiment:\n  max_iterations: 0\n",
            "experiment:\n  max_iterations: 4294967297\n",
            "experiment:\n  refine_steps: 4294967296\n",
            "experiment:\n  max_latency_growth: 0.5\n",
            "experiment:\n  max_latency_growth: nan\n",
        ] {
            assert!(
                BenchConfig::from_json(&yaml::parse(y).unwrap()).is_err(),
                "should reject: {y}"
            );
        }
    }
}
