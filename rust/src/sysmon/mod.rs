//! External system monitoring substrate (the paper's Pika + MetricQ roles).
//!
//! Sec. 3.4: "MetricQ was used to collect energy consumption data … and
//! other system metrics including CPU usage, system usage (memory
//! bandwidth, FLOP, instructions per cycle, filesystem read/write), and
//! network usage were collected using Pika."
//!
//! Neither facility exists off the TU-Dresden clusters, so this module
//! derives the same series from component activity: an [`ActivityModel`]
//! maps observed event/byte deltas (from the throughput recorder) to
//! estimated CPU, memory-bandwidth, FLOP, filesystem and network usage of
//! a [`NodeSpec`]; the energy sampler integrates a linear power model over
//! utilisation.  Trends (utilisation ∝ load, energy ∝ time×load) are what
//! the benchmark reports; absolute values are the node model's.

use std::sync::Arc;

use crate::metrics::{MeasurementPoint, MetricStore, ThroughputRecorder, ThroughputSnapshot};
use crate::util::clock::ClockRef;

/// Hardware model of one node (defaults: Barnard — dual Xeon 8470,
/// 104 cores, 512 GB DDR5-4800, ~16 GB/s/channel × 16 channels).
#[derive(Clone, Debug)]
pub struct NodeSpec {
    pub cores: u32,
    pub peak_membw_bytes_per_sec: f64,
    pub peak_flops: f64,
    pub idle_watts: f64,
    pub peak_watts: f64,
}

impl Default for NodeSpec {
    fn default() -> Self {
        Self {
            cores: 104,
            peak_membw_bytes_per_sec: 307e9, // 16 × DDR5-4800 ≈ 307 GB/s
            peak_flops: 6.6e12,              // 2×(52c × 2 AVX-512 FMA × 2.0 GHz × 16)
            idle_watts: 240.0,
            peak_watts: 700.0,
        }
    }
}

/// Per-event resource cost model (how much machine one event consumes).
#[derive(Clone, Debug)]
pub struct ActivityModel {
    pub cpu_micros_per_event: f64,
    /// Memory traffic per event byte moved through the pipeline.
    pub membw_amplification: f64,
    pub flops_per_event: f64,
    pub fs_bytes_per_event: f64,
    pub net_amplification: f64,
}

impl Default for ActivityModel {
    fn default() -> Self {
        Self {
            cpu_micros_per_event: 1.2,
            membw_amplification: 6.0, // serialize + broker + parse + compute
            flops_per_event: 24.0,
            fs_bytes_per_event: 0.0, // broker is in-memory here
            net_amplification: 2.0,  // in + out of the broker
        }
    }
}

/// Pika-like + MetricQ-like sampler.
pub struct SysmonSampler {
    clock: ClockRef,
    store: Arc<MetricStore>,
    recorder: Arc<ThroughputRecorder>,
    node: NodeSpec,
    model: ActivityModel,
    last: Option<(u64, ThroughputSnapshot)>,
    joules_total: f64,
}

impl SysmonSampler {
    pub fn new(
        clock: ClockRef,
        store: Arc<MetricStore>,
        recorder: Arc<ThroughputRecorder>,
        node: NodeSpec,
        model: ActivityModel,
    ) -> Self {
        Self {
            clock,
            store,
            recorder,
            node,
            model,
            last: None,
            joules_total: 0.0,
        }
    }

    /// Take one sample: derive system metrics from activity since the last
    /// call and append them to the store.
    pub fn sample(&mut self) {
        let now = self.clock.now_micros();
        let snap = self.recorder.snapshot();
        let Some((t_prev, prev)) = self.last.replace((now, snap)) else {
            return; // first call establishes the baseline
        };
        let dt = now.saturating_sub(t_prev);
        if dt == 0 {
            return;
        }
        let dt_secs = dt as f64 / 1e6;
        // Processed events/bytes: use the engine-output point as "work done".
        let ev_rate = snap.rate_events(&prev, MeasurementPoint::ProcOut, dt);
        let generated_rate = snap.rate_events(&prev, MeasurementPoint::DriverOut, dt);
        let work_rate = if ev_rate > 0.0 { ev_rate } else { generated_rate };
        let byte_rate = {
            let b = snap.rate_bytes(&prev, MeasurementPoint::ProcOut, dt);
            if b > 0.0 {
                b
            } else {
                snap.rate_bytes(&prev, MeasurementPoint::DriverOut, dt)
            }
        };

        let busy_cores = work_rate * self.model.cpu_micros_per_event / 1e6;
        let cpu_util = (busy_cores / self.node.cores as f64).min(1.0);
        let membw = byte_rate * self.model.membw_amplification;
        let membw_util = (membw / self.node.peak_membw_bytes_per_sec).min(1.0);
        let flops = work_rate * self.model.flops_per_event;
        let fs_rate = work_rate * self.model.fs_bytes_per_event;
        let net_rate = byte_rate * self.model.net_amplification;

        // MetricQ role: linear power model integrated into joules.
        let util = cpu_util.max(membw_util);
        let watts = self.node.idle_watts + (self.node.peak_watts - self.node.idle_watts) * util;
        self.joules_total += watts * dt_secs;

        self.store.append("sys.cpu_util", now, cpu_util);
        self.store.append("sys.busy_cores", now, busy_cores);
        self.store.append("sys.membw_gbps", now, membw / 1e9);
        self.store.append("sys.flops_g", now, flops / 1e9);
        self.store.append("sys.fs_mbps", now, fs_rate / 1e6);
        self.store.append("sys.net_mbps", now, net_rate / 1e6);
        self.store.append("energy.watts", now, watts);
        self.store.append("energy.joules_total", now, self.joules_total);
    }

    pub fn joules_total(&self) -> f64 {
        self.joules_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock;

    fn setup() -> (ClockRef, Arc<MetricStore>, Arc<ThroughputRecorder>, SysmonSampler) {
        let clk = clock::sim();
        let store = Arc::new(MetricStore::new());
        let rec = Arc::new(ThroughputRecorder::new());
        let mon = SysmonSampler::new(
            clk.clone(),
            store.clone(),
            rec.clone(),
            NodeSpec::default(),
            ActivityModel::default(),
        );
        (clk, store, rec, mon)
    }

    #[test]
    fn first_sample_is_baseline_only() {
        let (_, store, _, mut mon) = setup();
        mon.sample();
        assert!(store.get("sys.cpu_util").is_none());
    }

    #[test]
    fn utilisation_tracks_load() {
        let (clk, store, rec, mut mon) = setup();
        mon.sample();
        // 1M events in 1s at default 1.2us/event → 1.2 busy cores.
        rec.record_events(MeasurementPoint::ProcOut, 1_000_000, 27_000_000);
        clk.sleep_micros(1_000_000);
        mon.sample();
        let busy = store.get("sys.busy_cores").unwrap().last().unwrap().1;
        assert!((busy - 1.2).abs() < 0.01, "busy={busy}");
        let util = store.get("sys.cpu_util").unwrap().last().unwrap().1;
        assert!((util - 1.2 / 104.0).abs() < 1e-4);
    }

    #[test]
    fn energy_integrates_over_time() {
        let (clk, store, rec, mut mon) = setup();
        mon.sample();
        for _ in 0..5 {
            rec.record_events(MeasurementPoint::ProcOut, 100_000, 2_700_000);
            clk.sleep_micros(1_000_000);
            mon.sample();
        }
        let joules = store.get("energy.joules_total").unwrap();
        assert_eq!(joules.len(), 5);
        // Monotone non-decreasing and at least idle power × 5s.
        let vals: Vec<f64> = joules.values().collect();
        assert!(vals.windows(2).all(|w| w[1] >= w[0]));
        assert!(vals[4] >= 240.0 * 5.0 * 0.99, "joules={}", vals[4]);
        assert!((mon.joules_total() - vals[4]).abs() < 1e-9);
    }

    #[test]
    fn idle_system_draws_idle_power() {
        let (clk, store, _, mut mon) = setup();
        mon.sample();
        clk.sleep_micros(1_000_000);
        mon.sample();
        let watts = store.get("energy.watts").unwrap().last().unwrap().1;
        assert!((watts - 240.0).abs() < 1.0);
    }

    #[test]
    fn utilisation_saturates_at_one() {
        let (clk, store, rec, mut mon) = setup();
        mon.sample();
        rec.record_events(MeasurementPoint::ProcOut, 2_000_000_000, 54_000_000_000);
        clk.sleep_micros(1_000_000);
        mon.sample();
        assert_eq!(store.get("sys.cpu_util").unwrap().last().unwrap().1, 1.0);
    }
}
