//! PJRT runtime: load AOT HLO artifacts and execute them on the hot path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  HLO **text** is the interchange format
//! (jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1's
//! proto path rejects; the text parser reassigns ids).
//!
//! Threading: the PJRT client wrapper is `Rc`-based (not `Send`), so a
//! [`Runtime`] is **thread-confined**.  Engine tasks build one each from
//! the cheap, sendable [`RuntimeFactory`]; compilation happens once per
//! thread at startup and is cached thereafter — never on the per-batch
//! path.

pub mod manifest;

pub use manifest::{Artifact, DType, IoSpec, Manifest};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Cheap, sendable handle that thread-confined [`Runtime`]s are built from.
#[derive(Clone, Debug)]
pub struct RuntimeFactory {
    dir: PathBuf,
}

impl RuntimeFactory {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Self {
        Self {
            dir: artifacts_dir.as_ref().to_path_buf(),
        }
    }

    /// Default location: `<repo>/artifacts`.
    pub fn default_dir() -> Self {
        Self::new(Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Whether artifacts have been built.
    pub fn available(&self) -> bool {
        self.dir.join("manifest.json").exists()
    }

    /// Create a thread-local runtime (loads manifest, creates PJRT client).
    pub fn create(&self) -> Result<Runtime, String> {
        let manifest = Manifest::load(&self.dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        Ok(Runtime {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
        })
    }
}

/// One tensor argument for execution.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl Input<'_> {
    fn len(&self) -> usize {
        match self {
            Input::F32(v) => v.len(),
            Input::I32(v) => v.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Input::F32(_) => DType::F32,
            Input::I32(_) => DType::I32,
        }
    }

    fn to_literal(&self) -> xla::Literal {
        match self {
            Input::F32(v) => xla::Literal::vec1(v),
            Input::I32(v) => xla::Literal::vec1(v),
        }
    }
}

/// Thread-confined executor over the artifact set.
pub struct Runtime {
    manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch cached) the named artifact.
    fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>, String> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let artifact = self
            .manifest
            .by_name(name)
            .ok_or_else(|| format!("unknown artifact '{name}'"))?;
        let path = self.manifest.hlo_path(artifact);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| format!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {name}: {e}"))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every variant of `program` (startup warm).
    pub fn warm(&self, program: &str) -> Result<usize, String> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .filter(|a| a.program == program)
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }

    /// Execute artifact `name` with `inputs`, returning every output as a
    /// flat `f32` vector (all our programs emit f32 tensors).
    ///
    /// Validates input arity/dtype/length against the manifest before
    /// touching PJRT so shape bugs fail with readable errors.
    pub fn execute_f32(&self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>, String> {
        let artifact = self
            .manifest
            .by_name(name)
            .ok_or_else(|| format!("unknown artifact '{name}'"))?;
        if inputs.len() != artifact.inputs.len() {
            return Err(format!(
                "{name}: expected {} inputs, got {}",
                artifact.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (spec, arg)) in artifact.inputs.iter().zip(inputs).enumerate() {
            if spec.dtype != arg.dtype() {
                return Err(format!("{name}: input {i} dtype mismatch"));
            }
            if spec.elements() != arg.len() {
                return Err(format!(
                    "{name}: input {i} length {} != expected {}",
                    arg.len(),
                    spec.elements()
                ));
            }
        }
        let exe = self.executable(name)?;
        let literals: Vec<xla::Literal> = inputs.iter().map(|i| i.to_literal()).collect();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| format!("execute {name}: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch {name}: {e}"))?;
        // aot.py lowers with return_tuple=True: unwrap the output tuple.
        let parts = out.to_tuple().map_err(|e| format!("untuple {name}: {e}"))?;
        if parts.len() != artifact.outputs.len() {
            return Err(format!(
                "{name}: expected {} outputs, got {}",
                artifact.outputs.len(),
                parts.len()
            ));
        }
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| format!("read {name}: {e}")))
            .collect()
    }

    /// Convenience: select a variant of `program` for `batch` and return
    /// the artifact (marshalling decisions live with the caller).
    pub fn select(&self, program: &str, batch: usize) -> Result<&Artifact, String> {
        self.manifest
            .select(program, batch)
            .ok_or_else(|| format!("no artifact for program '{program}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn factory() -> Option<RuntimeFactory> {
        let f = RuntimeFactory::default_dir();
        if f.available() {
            Some(f)
        } else {
            eprintln!("skipping runtime test: run `make artifacts` first");
            None
        }
    }

    #[test]
    fn cpu_pipeline_executes_and_matches_oracle() {
        let Some(f) = factory() else { return };
        let rt = f.create().unwrap();
        let temps: Vec<f32> = (0..1024).map(|i| (i as f32) / 10.0 - 40.0).collect();
        let thresh = [80.0f32];
        let out = rt
            .execute_f32("cpu_b1024", &[Input::F32(&temps), Input::F32(&thresh)])
            .unwrap();
        assert_eq!(out.len(), 2);
        let (fahr, alerts) = (&out[0], &out[1]);
        for i in 0..1024 {
            let expect = temps[i] * 9.0 / 5.0 + 32.0;
            assert!((fahr[i] - expect).abs() < 1e-3, "i={i}");
            let expect_alert = if expect > 80.0 { 1.0 } else { 0.0 };
            assert_eq!(alerts[i], expect_alert, "i={i}");
        }
    }

    #[test]
    fn mem_pipeline_accumulates_state() {
        let Some(f) = factory() else { return };
        let rt = f.create().unwrap();
        let ids: Vec<i32> = (0..1024).map(|i| (i % 16) as i32).collect();
        let temps: Vec<f32> = vec![2.0; 1024];
        let zeros = vec![0.0f32; 1024];
        let out = rt
            .execute_f32(
                "mem_b1024_k1024",
                &[
                    Input::I32(&ids),
                    Input::F32(&temps),
                    Input::F32(&zeros),
                    Input::F32(&zeros),
                ],
            )
            .unwrap();
        let (sum, cnt, avg) = (&out[0], &out[1], &out[2]);
        for k in 0..16 {
            assert!((sum[k] - 128.0).abs() < 1e-3, "k={k} sum={}", sum[k]);
            assert_eq!(cnt[k], 64.0);
            assert!((avg[k] - 2.0).abs() < 1e-4);
        }
        assert_eq!(cnt[16], 0.0);
        // Feed state back: counts double.
        let out2 = rt
            .execute_f32(
                "mem_b1024_k1024",
                &[
                    Input::I32(&ids),
                    Input::F32(&temps),
                    Input::F32(sum),
                    Input::F32(cnt),
                ],
            )
            .unwrap();
        assert_eq!(out2[1][0], 128.0);
    }

    #[test]
    fn padded_ids_are_dropped() {
        let Some(f) = factory() else { return };
        let rt = f.create().unwrap();
        // Half the batch is padding (id == keys).
        let ids: Vec<i32> = (0..1024).map(|i| if i < 512 { 0 } else { 1024 }).collect();
        let temps = vec![1.0f32; 1024];
        let zeros = vec![0.0f32; 1024];
        let out = rt
            .execute_f32(
                "mem_b1024_k1024",
                &[
                    Input::I32(&ids),
                    Input::F32(&temps),
                    Input::F32(&zeros),
                    Input::F32(&zeros),
                ],
            )
            .unwrap();
        assert_eq!(out[1][0], 512.0, "only real slots counted");
        let total: f32 = out[1].iter().sum();
        assert_eq!(total, 512.0, "padding leaked into some key");
    }

    #[test]
    fn input_validation_catches_mistakes() {
        let Some(f) = factory() else { return };
        let rt = f.create().unwrap();
        let short = vec![0.0f32; 10];
        let th = [0.0f32];
        // Wrong length.
        assert!(rt
            .execute_f32("cpu_b1024", &[Input::F32(&short), Input::F32(&th)])
            .is_err());
        // Wrong arity.
        assert!(rt.execute_f32("cpu_b1024", &[Input::F32(&short)]).is_err());
        // Unknown name.
        assert!(rt.execute_f32("nope", &[]).is_err());
        // Wrong dtype.
        let ids = vec![0i32; 1024];
        assert!(rt
            .execute_f32("cpu_b1024", &[Input::I32(&ids), Input::F32(&th)])
            .is_err());
    }

    #[test]
    fn executables_are_cached() {
        let Some(f) = factory() else { return };
        let rt = f.create().unwrap();
        let temps = vec![0.0f32; 256];
        let th = [0.0f32];
        let t0 = std::time::Instant::now();
        rt.execute_f32("cpu_b256", &[Input::F32(&temps), Input::F32(&th)])
            .unwrap();
        let first = t0.elapsed();
        let t1 = std::time::Instant::now();
        for _ in 0..10 {
            rt.execute_f32("cpu_b256", &[Input::F32(&temps), Input::F32(&th)])
                .unwrap();
        }
        let ten_more = t1.elapsed();
        // 10 cached executions should be far cheaper than 1 compile+run.
        assert!(ten_more < first * 5, "first={first:?} ten_more={ten_more:?}");
    }

    #[test]
    fn warm_compiles_all_variants() {
        let Some(f) = factory() else { return };
        let rt = f.create().unwrap();
        assert_eq!(rt.warm("cpu_pipeline_step").unwrap(), 3);
    }
}
