//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `artifacts/manifest.json` lists every AOT-lowered HLO module with its
//! I/O signature; the runtime keys executable selection and marshalling
//! off this file and never guesses shapes.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Element type of a tensor input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self, String> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(format!("unsupported dtype '{other}'")),
        }
    }
}

/// One tensor signature.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self, String> {
        let dtype = DType::parse(
            j.get("dtype")
                .and_then(|d| d.as_str())
                .ok_or("io spec missing dtype")?,
        )?;
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or("io spec missing shape")?
            .iter()
            .map(|v| v.as_i64().map(|i| i as usize).ok_or("bad dim"))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Self { dtype, shape })
    }
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    /// L2 program this artifact lowers ("cpu_pipeline_step", …).
    pub program: String,
    pub batch: usize,
    pub keys: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub source_sha256: String,
    pub artifacts: Vec<Artifact>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn parse(dir: &Path, text: &str) -> Result<Self, String> {
        let j = json::parse(text).map_err(|e| e.to_string())?;
        let version = j.get("version").and_then(|v| v.as_i64()).unwrap_or(0);
        if version != 1 {
            return Err(format!("manifest version {version} unsupported (want 1)"));
        }
        let source_sha256 = j
            .get("source_sha256")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string();
        let mut artifacts = Vec::new();
        for entry in j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or("manifest missing artifacts list")?
        {
            let name = entry
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("artifact missing name")?
                .to_string();
            let file = entry
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or("artifact missing file")?
                .to_string();
            let program = entry
                .get("program")
                .and_then(|v| v.as_str())
                .ok_or("artifact missing program")?
                .to_string();
            let batch = entry.get("batch").and_then(|v| v.as_i64()).unwrap_or(0) as usize;
            let keys = entry.get("keys").and_then(|v| v.as_i64()).unwrap_or(0) as usize;
            let inputs = entry
                .get("inputs")
                .and_then(|a| a.as_arr())
                .ok_or("artifact missing inputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?;
            let outputs = entry
                .get("outputs")
                .and_then(|a| a.as_arr())
                .ok_or("artifact missing outputs")?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>, _>>()?;
            artifacts.push(Artifact {
                name,
                file,
                program,
                batch,
                keys,
                inputs,
                outputs,
            });
        }
        Ok(Self {
            source_sha256,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            )
        })?;
        Self::parse(dir, &text)
    }

    pub fn by_name(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Pick the best batch-size variant of `program` for `batch` events:
    /// the smallest variant with `variant.batch >= batch`, else the
    /// largest available (the batcher then splits).
    pub fn select(&self, program: &str, batch: usize) -> Option<&Artifact> {
        let mut candidates: Vec<&Artifact> = self
            .artifacts
            .iter()
            .filter(|a| a.program == program)
            .collect();
        candidates.sort_by_key(|a| a.batch);
        candidates
            .iter()
            .find(|a| a.batch >= batch)
            .copied()
            .or_else(|| candidates.last().copied())
    }

    pub fn hlo_path(&self, artifact: &Artifact) -> PathBuf {
        self.dir.join(&artifact.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "source_sha256": "abc",
      "artifacts": [
        {"name": "cpu_b256", "file": "cpu_b256.hlo.txt",
         "program": "cpu_pipeline_step", "batch": 256, "keys": 0,
         "inputs": [{"dtype": "float32", "shape": [256]},
                    {"dtype": "float32", "shape": [1]}],
         "outputs": [{"dtype": "float32", "shape": [256]},
                     {"dtype": "float32", "shape": [256]}]},
        {"name": "cpu_b1024", "file": "cpu_b1024.hlo.txt",
         "program": "cpu_pipeline_step", "batch": 1024, "keys": 0,
         "inputs": [{"dtype": "float32", "shape": [1024]},
                    {"dtype": "float32", "shape": [1]}],
         "outputs": [{"dtype": "float32", "shape": [1024]},
                     {"dtype": "float32", "shape": [1024]}]},
        {"name": "mem_b256_k1024", "file": "mem.hlo.txt",
         "program": "mem_pipeline_step", "batch": 256, "keys": 1024,
         "inputs": [{"dtype": "int32", "shape": [256]},
                    {"dtype": "float32", "shape": [256]},
                    {"dtype": "float32", "shape": [1024]},
                    {"dtype": "float32", "shape": [1024]}],
         "outputs": [{"dtype": "float32", "shape": [1024]},
                     {"dtype": "float32", "shape": [1024]},
                     {"dtype": "float32", "shape": [1024]}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        let cpu = m.by_name("cpu_b256").unwrap();
        assert_eq!(cpu.batch, 256);
        assert_eq!(cpu.inputs[0].dtype, DType::F32);
        assert_eq!(cpu.inputs[0].elements(), 256);
        let mem = m.by_name("mem_b256_k1024").unwrap();
        assert_eq!(mem.inputs[0].dtype, DType::I32);
        assert_eq!(mem.keys, 1024);
    }

    #[test]
    fn select_prefers_smallest_sufficient_batch() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        assert_eq!(m.select("cpu_pipeline_step", 100).unwrap().batch, 256);
        assert_eq!(m.select("cpu_pipeline_step", 256).unwrap().batch, 256);
        assert_eq!(m.select("cpu_pipeline_step", 257).unwrap().batch, 1024);
        // Larger than any variant: take the largest.
        assert_eq!(m.select("cpu_pipeline_step", 9999).unwrap().batch, 1024);
        assert!(m.select("unknown", 1).is_none());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 2");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Exercises the real artifacts when `make artifacts` has run.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.by_name("cpu_b1024").is_some());
            assert!(m.by_name("mem_b1024_k1024").is_some());
            assert!(m.by_name("fused_b1024_k1024").is_some());
        }
    }
}
