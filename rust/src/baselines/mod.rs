//! Baseline benchmark-suite generator models (Table 1).
//!
//! The paper's Table 1 compares the *maximum documented throughput* of
//! seven existing DSP benchmark suites against SProBench's generator
//! (0.1–1 M ev/s vs 40 M ev/s).  The original suites are JVM/C++ code
//! bases we cannot run here, so each is modelled by (a) its **documented
//! peak rate** — reproduced from Table 1 and each suite's paper — applied
//! as a hard rate cap, and (b) the **mechanistic inefficiency** its design
//! carries (global synchronization, per-event allocation churn,
//! heavyweight record formats, per-item pipeline stages), which the model
//! actually executes per event.  The Table 1 bench then *measures* every
//! model under the same harness: baselines saturate at their caps (or
//! earlier, if the mechanistic cost binds), while the SProBench generator
//! runs uncapped — reproducing the ordering and the ≥10× gap.
//!
//! DESIGN.md §1 documents this substitution.

use std::sync::Mutex;

use crate::util::clock::ClockRef;
use crate::util::rng::Pcg32;
use crate::wgen::{EventFormat, SensorEvent, TokenBucket};

/// Mechanistic per-event inefficiencies a suite's generator design carries.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostModel {
    /// Acquire a global lock per event (single shared emitter queue).
    pub global_lock: bool,
    /// Fresh heap allocations per event (boxed tuples, maps, strings).
    pub allocs_per_event: u32,
    /// String fields formatted per event (format! machinery).
    pub fmt_fields: u32,
    /// Fixed extra CPU per event, nanoseconds (validation, DB hooks, …).
    pub busywork_nanos: u64,
}

/// One modelled suite.
#[derive(Clone, Debug)]
pub struct BaselineSpec {
    pub name: &'static str,
    /// Max documented throughput, events/second (Table 1 column).
    pub doc_rate: f64,
    /// Whether the suite documents multi-instance scaling of its generator.
    pub scales_out: bool,
    pub cost: CostModel,
}

/// The seven suites of Table 1 (SProBench itself is measured, not modelled).
pub fn all_baselines() -> Vec<BaselineSpec> {
    vec![
        BaselineSpec {
            // Single driver emitting simulated toll-road tuples through one
            // historical-data validator.
            name: "LinearRoad",
            doc_rate: 0.1e6,
            scales_out: false,
            cost: CostModel {
                global_lock: true,
                allocs_per_event: 4,
                fmt_fields: 6,
                busywork_nanos: 4_000,
            },
        },
        BaselineSpec {
            // Ad-campaign JSON events, Redis lookups on the path.
            name: "YSB",
            doc_rate: 0.2e6,
            scales_out: false,
            cost: CostModel {
                global_lock: false,
                allocs_per_event: 6,
                fmt_fields: 7,
                busywork_nanos: 2_500,
            },
        },
        BaselineSpec {
            name: "DSPBench",
            doc_rate: 0.8e6,
            scales_out: false,
            cost: CostModel {
                global_lock: false,
                allocs_per_event: 3,
                fmt_fields: 4,
                busywork_nanos: 600,
            },
        },
        BaselineSpec {
            // Kubernetes-native; generator pods scale but per-pod rate is
            // the documented 1 M/s bound.
            name: "Theodolite",
            doc_rate: 1.0e6,
            scales_out: true,
            cost: CostModel {
                global_lock: false,
                allocs_per_event: 2,
                fmt_fields: 3,
                busywork_nanos: 400,
            },
        },
        BaselineSpec {
            // Enterprise pipeline with result validation against a DBMS.
            name: "ESPBench",
            doc_rate: 0.1e6,
            scales_out: false,
            cost: CostModel {
                global_lock: true,
                allocs_per_event: 5,
                fmt_fields: 8,
                busywork_nanos: 5_000,
            },
        },
        BaselineSpec {
            // C++/FastFlow; items are video frames / compression blocks —
            // per-item cost is enormous, rates are in K/s.
            name: "SPBench",
            doc_rate: 0.5e3,
            scales_out: false,
            cost: CostModel {
                global_lock: false,
                allocs_per_event: 2,
                fmt_fields: 1,
                busywork_nanos: 1_900_000,
            },
        },
        BaselineSpec {
            name: "OSPBench",
            doc_rate: 0.8e6,
            scales_out: false,
            cost: CostModel {
                global_lock: false,
                allocs_per_event: 3,
                fmt_fields: 5,
                busywork_nanos: 700,
            },
        },
    ]
}

/// Result of driving one generator model.
#[derive(Clone, Copy, Debug)]
pub struct GenResult {
    pub events: u64,
    pub bytes: u64,
    pub elapsed_micros: u64,
    pub rate: f64,
}

/// Drive a baseline generator model for `events` events (or until
/// `deadline_micros` elapses), sinking serialized payloads.
pub fn run_baseline(
    spec: &BaselineSpec,
    events: u64,
    deadline_micros: u64,
    clock: &ClockRef,
) -> GenResult {
    let start = clock.now_micros();
    let mut bucket = TokenBucket::new(clock.clone(), spec.doc_rate as u64, (spec.doc_rate / 20.0) as u64 + 64);
    let lock = Mutex::new(());
    let mut rng = Pcg32::new(7, 7);
    let mut emitted = 0u64;
    let mut bytes = 0u64;
    let mut sink = 0u64;

    while emitted < events {
        if clock.now_micros().saturating_sub(start) > deadline_micros {
            break;
        }
        // Rate cap: the documented peak.
        bucket.acquire(1);
        // Mechanistic per-event cost.
        if spec.cost.global_lock {
            let _g = lock.lock().expect("baseline lock");
            sink = sink.wrapping_add(1);
        }
        let mut payload = String::new();
        for f in 0..spec.cost.fmt_fields {
            payload.push_str(&format!("\"f{}\":{},", f, rng.next_u32()));
        }
        for _ in 0..spec.cost.allocs_per_event {
            // Boxed per-event garbage a JVM generator would churn.
            let garbage: Box<Vec<u8>> = Box::new(vec![0u8; 32]);
            sink = sink.wrapping_add(garbage.len() as u64);
        }
        busywork(spec.cost.busywork_nanos, clock);
        bytes += payload.len() as u64;
        emitted += 1;
        std::hint::black_box(&payload);
    }
    std::hint::black_box(sink);
    let elapsed = clock.now_micros().saturating_sub(start).max(1);
    GenResult {
        events: emitted,
        bytes,
        elapsed_micros: elapsed,
        rate: emitted as f64 * 1e6 / elapsed as f64,
    }
}

/// The SProBench generator inner loop, measured under the same harness
/// (serializer + key draw, no caps, no per-event allocation).
pub fn run_sprobench_generator(
    events: u64,
    event_bytes: usize,
    clock: &ClockRef,
) -> GenResult {
    let start = clock.now_micros();
    let mut rng = Pcg32::new(42, 1);
    let mut wire = Vec::with_capacity(event_bytes + 16);
    let mut bytes = 0u64;
    let format = if event_bytes < 40 {
        EventFormat::Csv
    } else {
        EventFormat::Json
    };
    let mut serializer = crate::wgen::EventSerializer::new(format, event_bytes);
    for _ in 0..events {
        let ev = SensorEvent {
            ts_micros: start,
            sensor_id: rng.below(1024),
            temp_c: 20.0 + rng.f32() * 30.0,
        };
        bytes += serializer.serialize(&ev, &mut wire) as u64;
        std::hint::black_box(&wire);
    }
    let elapsed = clock.now_micros().saturating_sub(start).max(1);
    GenResult {
        events,
        bytes,
        elapsed_micros: elapsed,
        rate: events as f64 * 1e6 / elapsed as f64,
    }
}

fn busywork(nanos: u64, clock: &ClockRef) {
    if nanos == 0 {
        return;
    }
    if clock.is_virtual() {
        clock.sleep_micros(nanos / 1_000);
        return;
    }
    let start = std::time::Instant::now();
    while (start.elapsed().as_nanos() as u64) < nanos {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock;

    #[test]
    fn table1_ordering_is_encoded() {
        let b = all_baselines();
        let get = |n: &str| b.iter().find(|s| s.name == n).unwrap().doc_rate;
        assert!(get("Theodolite") >= get("DSPBench"));
        assert!(get("DSPBench") > get("YSB"));
        assert!(get("YSB") > get("LinearRoad"));
        assert!(get("LinearRoad") > get("SPBench"));
        // SProBench's documented 40 M/s dwarfs the best baseline ×10+.
        assert!(40e6 / get("Theodolite") >= 10.0);
    }

    #[test]
    fn baselines_respect_their_caps() {
        let clk = clock::wall();
        for spec in all_baselines().iter().filter(|s| s.doc_rate >= 1e5) {
            let r = run_baseline(spec, 20_000, 2_000_000, &clk);
            assert!(
                r.rate <= spec.doc_rate * 1.15,
                "{}: measured {:.0} > cap {:.0}",
                spec.name,
                r.rate,
                spec.doc_rate
            );
        }
    }

    #[test]
    fn spbench_is_orders_of_magnitude_slower() {
        let clk = clock::wall();
        let b = all_baselines();
        let sp = b.iter().find(|s| s.name == "SPBench").unwrap();
        let r = run_baseline(sp, 50, 1_000_000, &clk);
        assert!(r.rate < 2_000.0, "SPBench rate {:.0}", r.rate);
    }

    #[test]
    fn sprobench_generator_beats_every_baseline_cap() {
        let clk = clock::wall();
        let r = run_sprobench_generator(200_000, 27, &clk);
        // Must beat the fastest baseline's documented 1 M/s on any box.
        assert!(
            r.rate > 1.0e6,
            "generator too slow for the Table 1 claim: {:.0}/s",
            r.rate
        );
        assert_eq!(r.bytes, 200_000 * 27);
    }

    #[test]
    fn deadline_bounds_runtime() {
        let clk = clock::wall();
        let b = all_baselines();
        let lr = b.iter().find(|s| s.name == "LinearRoad").unwrap();
        let t0 = std::time::Instant::now();
        let r = run_baseline(lr, u64::MAX, 200_000, &clk);
        assert!(t0.elapsed().as_secs() < 5);
        assert!(r.events > 0);
    }
}
