//! Simulated JVM managed heap + garbage collector, with a JMX-style sampler.
//!
//! The paper's frameworks are JVM-based and Sec. 3.4 collects "memory usage
//! and garbage collection (time and count)" through the JMX API; Fig. 8c
//! shows young-GC count and duration growing over the run and with
//! parallelism.  This substrate reproduces the mechanism behind that
//! curve: processing allocates; allocation fills the young generation;
//! young collections promote survivors; promoted bytes accumulate until a
//! (much costlier) old collection.  Pause times stall the allocating
//! thread in wall mode — exactly how a stop-the-world young pause shows up
//! in end-to-end latency.
//!
//! * [`heap::JvmHeap`] — the allocator + GC state machine.
//! * [`jmx::JmxSampler`] — periodic snapshot into the central metric store.

pub mod heap;
pub mod jmx;

pub use heap::{GcConfig, GcStats, JvmHeap};
pub use jmx::JmxSampler;
