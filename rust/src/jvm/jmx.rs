//! JMX-style sampler: periodic heap/GC snapshots into the metric store.
//!
//! The paper designs "a Java based application … that relies on the JMX
//! API to gather all process metrics" (Sec. 3.4).  Here the sampler walks
//! every registered heap and appends the JMX bean equivalents as time
//! series: `jvm.<name>.gc_young_count`, `.gc_young_time_ms`,
//! `.gc_old_count`, `.gc_old_time_ms`, `.heap_used_mb`, `.alloc_mb`.

use std::sync::Arc;

use super::heap::JvmHeap;
use crate::metrics::MetricStore;
use crate::util::clock::ClockRef;

/// Registered heaps, sampled together.
pub struct JmxSampler {
    clock: ClockRef,
    store: Arc<MetricStore>,
    heaps: Vec<(String, Arc<JvmHeap>)>,
}

impl JmxSampler {
    pub fn new(clock: ClockRef, store: Arc<MetricStore>) -> Self {
        Self {
            clock,
            store,
            heaps: Vec::new(),
        }
    }

    /// Register a component heap under a JMX-ish name ("engine-task-3").
    pub fn register(&mut self, name: &str, heap: Arc<JvmHeap>) {
        self.heaps.push((name.to_string(), heap));
    }

    pub fn heap_count(&self) -> usize {
        self.heaps.len()
    }

    /// Take one sample of every registered heap.
    pub fn sample(&self) {
        let t = self.clock.now_micros();
        for (name, heap) in &self.heaps {
            let s = heap.stats();
            self.store
                .append(&format!("jvm.{name}.gc_young_count"), t, s.young_count as f64);
            self.store.append(
                &format!("jvm.{name}.gc_young_time_ms"),
                t,
                s.young_time_micros as f64 / 1e3,
            );
            self.store
                .append(&format!("jvm.{name}.gc_old_count"), t, s.old_count as f64);
            self.store.append(
                &format!("jvm.{name}.gc_old_time_ms"),
                t,
                s.old_time_micros as f64 / 1e3,
            );
            self.store.append(
                &format!("jvm.{name}.heap_used_mb"),
                t,
                (s.young_used + s.old_used) as f64 / (1 << 20) as f64,
            );
            self.store.append(
                &format!("jvm.{name}.alloc_mb"),
                t,
                s.allocated_bytes as f64 / (1 << 20) as f64,
            );
        }
    }

    /// Aggregate young-GC count and time across all heaps (Fig. 8c series).
    pub fn aggregate_young(&self) -> (u64, u64) {
        self.heaps
            .iter()
            .map(|(_, h)| {
                let s = h.stats();
                (s.young_count, s.young_time_micros)
            })
            .fold((0, 0), |(c, t), (dc, dt)| (c + dc, t + dt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jvm::heap::GcConfig;
    use crate::util::clock;

    #[test]
    fn sampler_emits_all_series() {
        let clk = clock::sim();
        let store = Arc::new(MetricStore::new());
        let mut jmx = JmxSampler::new(clk.clone(), store.clone());
        let heap = Arc::new(JvmHeap::new(
            GcConfig {
                young_bytes: 1 << 20,
                stall: false,
                ..GcConfig::default()
            },
            clk.clone(),
        ));
        jmx.register("engine-0", heap.clone());
        heap.alloc(3 << 20);
        clk.sleep_micros(1_000_000);
        jmx.sample();
        let counts = store.get("jvm.engine-0.gc_young_count").unwrap();
        assert_eq!(counts.len(), 1);
        assert_eq!(counts.points[0].1, 3.0);
        assert!(store.get("jvm.engine-0.heap_used_mb").is_some());
        assert!(store.get("jvm.engine-0.alloc_mb").is_some());
    }

    #[test]
    fn aggregate_sums_heaps() {
        let clk = clock::sim();
        let store = Arc::new(MetricStore::new());
        let mut jmx = JmxSampler::new(clk.clone(), store);
        let mk = || {
            Arc::new(JvmHeap::new(
                GcConfig {
                    young_bytes: 1 << 20,
                    stall: false,
                    ..GcConfig::default()
                },
                clk.clone(),
            ))
        };
        let h1 = mk();
        let h2 = mk();
        jmx.register("a", h1.clone());
        jmx.register("b", h2.clone());
        h1.alloc(2 << 20);
        h2.alloc(1 << 20);
        let (count, time) = jmx.aggregate_young();
        assert_eq!(count, 3);
        assert!(time > 0);
    }
}
