//! The managed-heap model.
//!
//! Generational accounting only — no real memory moves.  Components call
//! [`JvmHeap::alloc`] with the bytes they would have allocated on a JVM
//! (event objects, deserialized tuples, window state).  The model:
//!
//! * young gen of `young_bytes`; allocation beyond it triggers a young GC,
//! * young GC: pause = `young_pause_base + young_pause_per_mb × live`,
//!   where live = `survivor_ratio × young fill`; survivors promote,
//! * old gen of `old_bytes`; promotion beyond it triggers a full GC with
//!   its own (larger) pause model, reclaiming `old_release_ratio`,
//! * pauses stall the calling thread (wall) / advance time (sim) when
//!   `stall` is set — GC cost is visible in latency, as on a real JVM.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::clock::ClockRef;

/// GC model parameters.
#[derive(Clone, Debug)]
pub struct GcConfig {
    pub young_bytes: u64,
    pub old_bytes: u64,
    /// Fraction of young-gen fill that survives a young collection.
    pub survivor_ratio: f64,
    pub young_pause_base_micros: u64,
    pub young_pause_per_mb_micros: u64,
    pub old_pause_base_micros: u64,
    pub old_pause_per_mb_micros: u64,
    /// Fraction of the old gen reclaimed by a full collection.
    pub old_release_ratio: f64,
    /// Stall the allocating thread for the pause duration.
    pub stall: bool,
}

impl Default for GcConfig {
    fn default() -> Self {
        Self {
            // Default: 256 MB young, 2 GB old — the paper gives workers
            // 2 GB heap per generator and 5 GB for Kafka.
            young_bytes: 256 << 20,
            old_bytes: 2 << 30,
            survivor_ratio: 0.10,
            young_pause_base_micros: 500,
            young_pause_per_mb_micros: 30,
            old_pause_base_micros: 20_000,
            old_pause_per_mb_micros: 80,
            old_release_ratio: 0.8,
            stall: true,
        }
    }
}

/// Cumulative GC statistics (the JMX view).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcStats {
    pub young_count: u64,
    pub young_time_micros: u64,
    pub old_count: u64,
    pub old_time_micros: u64,
    pub allocated_bytes: u64,
    pub young_used: u64,
    pub old_used: u64,
}

struct HeapState {
    young_used: u64,
    old_used: u64,
}

/// One simulated JVM heap (per component: generator / broker / engine task).
pub struct JvmHeap {
    config: GcConfig,
    clock: ClockRef,
    state: Mutex<HeapState>,
    young_count: AtomicU64,
    young_time: AtomicU64,
    old_count: AtomicU64,
    old_time: AtomicU64,
    allocated: AtomicU64,
}

impl JvmHeap {
    pub fn new(config: GcConfig, clock: ClockRef) -> Self {
        Self {
            config,
            clock,
            state: Mutex::new(HeapState {
                young_used: 0,
                old_used: 0,
            }),
            young_count: AtomicU64::new(0),
            young_time: AtomicU64::new(0),
            old_count: AtomicU64::new(0),
            old_time: AtomicU64::new(0),
            allocated: AtomicU64::new(0),
        }
    }

    /// Account an allocation; runs GC cycles when generations fill.
    /// Returns the total pause microseconds incurred (0 on the fast path).
    pub fn alloc(&self, bytes: u64) -> u64 {
        self.allocated.fetch_add(bytes, Ordering::Relaxed);
        let mut pause_total = 0u64;
        let mut st = self.state.lock().expect("heap state");
        st.young_used += bytes;
        while st.young_used >= self.config.young_bytes {
            pause_total += self.young_gc(&mut st);
        }
        if pause_total > 0 && self.config.stall {
            drop(st);
            self.clock.sleep_micros(pause_total);
        }
        pause_total
    }

    /// One young collection under the state lock. Returns its pause.
    fn young_gc(&self, st: &mut HeapState) -> u64 {
        let fill = st.young_used.min(self.config.young_bytes);
        let survivors = (fill as f64 * self.config.survivor_ratio) as u64;
        let live_mb = survivors >> 20;
        let pause = self.config.young_pause_base_micros
            + self.config.young_pause_per_mb_micros * live_mb;
        st.young_used = st.young_used.saturating_sub(self.config.young_bytes);
        st.old_used += survivors;
        self.young_count.fetch_add(1, Ordering::Relaxed);
        self.young_time.fetch_add(pause, Ordering::Relaxed);
        let mut total = pause;
        if st.old_used >= self.config.old_bytes {
            total += self.old_gc(st);
        }
        total
    }

    fn old_gc(&self, st: &mut HeapState) -> u64 {
        let live_mb = st.old_used >> 20;
        let pause =
            self.config.old_pause_base_micros + self.config.old_pause_per_mb_micros * live_mb;
        st.old_used = (st.old_used as f64 * (1.0 - self.config.old_release_ratio)) as u64;
        self.old_count.fetch_add(1, Ordering::Relaxed);
        self.old_time.fetch_add(pause, Ordering::Relaxed);
        pause
    }

    pub fn stats(&self) -> GcStats {
        let st = self.state.lock().expect("heap state");
        GcStats {
            young_count: self.young_count.load(Ordering::Relaxed),
            young_time_micros: self.young_time.load(Ordering::Relaxed),
            old_count: self.old_count.load(Ordering::Relaxed),
            old_time_micros: self.old_time.load(Ordering::Relaxed),
            allocated_bytes: self.allocated.load(Ordering::Relaxed),
            young_used: st.young_used,
            old_used: st.old_used,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock;

    fn small_heap(stall: bool) -> JvmHeap {
        JvmHeap::new(
            GcConfig {
                young_bytes: 1 << 20, // 1 MB young
                old_bytes: 4 << 20,   // 4 MB old
                survivor_ratio: 0.25,
                young_pause_base_micros: 100,
                young_pause_per_mb_micros: 10,
                old_pause_base_micros: 1_000,
                old_pause_per_mb_micros: 100,
                old_release_ratio: 1.0,
                stall,
            },
            clock::sim(),
        )
    }

    #[test]
    fn no_gc_below_young_capacity() {
        let h = small_heap(false);
        h.alloc(512 << 10);
        let s = h.stats();
        assert_eq!(s.young_count, 0);
        assert_eq!(s.young_used, 512 << 10);
    }

    #[test]
    fn young_gc_fires_and_promotes() {
        let h = small_heap(false);
        h.alloc(1 << 20); // exactly one young gen
        let s = h.stats();
        assert_eq!(s.young_count, 1);
        assert_eq!(s.young_used, 0);
        assert_eq!(s.old_used, 256 << 10, "25% survivors promoted");
        assert!(s.young_time_micros >= 100);
    }

    #[test]
    fn gc_count_scales_with_allocation_rate() {
        // The Fig. 8c mechanism: double the allocation → double the GCs.
        let h1 = small_heap(false);
        let h2 = small_heap(false);
        for _ in 0..64 {
            h1.alloc(256 << 10);
            h2.alloc(512 << 10);
        }
        let (s1, s2) = (h1.stats(), h2.stats());
        assert_eq!(s2.young_count, 2 * s1.young_count);
        assert!(s2.young_time_micros > s1.young_time_micros);
    }

    #[test]
    fn old_gc_fires_after_enough_promotion() {
        let h = small_heap(false);
        // Each young GC promotes 256 KB; the 4 MB old gen fills after 16.
        for _ in 0..20 {
            h.alloc(1 << 20);
        }
        let s = h.stats();
        assert!(s.old_count >= 1, "old GC never fired: {s:?}");
        assert!(s.old_time_micros >= 1_000);
    }

    #[test]
    fn stall_advances_clock() {
        let c = clock::sim();
        let h = JvmHeap::new(
            GcConfig {
                young_bytes: 1 << 20,
                stall: true,
                young_pause_base_micros: 777,
                young_pause_per_mb_micros: 0,
                ..GcConfig::default()
            },
            c.clone(),
        );
        let pause = h.alloc(1 << 20);
        assert_eq!(pause, 777);
        assert_eq!(c.now_micros(), 777);
    }

    #[test]
    fn giant_allocation_triggers_multiple_young_gcs() {
        let h = small_heap(false);
        h.alloc(5 << 20); // five young gens at once
        let s = h.stats();
        assert_eq!(s.young_count, 5);
    }

    #[test]
    fn concurrent_allocs_are_accounted() {
        use std::sync::Arc;
        let h = Arc::new(small_heap(false));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        h.alloc(1 << 10);
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.stats().allocated_bytes, 4 * 1000 * (1 << 10));
    }
}
