//! Memory-intensive pipeline (paper Sec. 3.3, blue path).
//!
//! Parses the sensor stream, keys it by sensor ID, and maintains a sliding
//! window over the temperatures; the per-key mean is kept as operator
//! state and emitted at every slide boundary.  The per-batch state update
//! is the `mem_pipeline_step` HLO artifact (L1 Pallas `keyed_window`
//! kernel: masked-matmul scatter into VMEM-resident accumulators), with a
//! native Rust path as the ablation baseline.
//!
//! Since the operator-chain redesign the production path is the canonical
//! `[window(mean), emit_aggregates]` chain; this struct is the reference
//! implementation the equivalence suite compares against.

use super::{Compute, PipelineStep, StepStats, HLO_KEYS};
use crate::broker::Record;
use crate::engine::{EventBatch, SlidingWindow, WindowEmit};
use crate::runtime::Input;

pub struct MemIntensive {
    compute: Compute,
    window: SlidingWindow,
    keys: usize,
    stats: StepStats,
    // Reused marshalling buffers.
    ids_pad: Vec<i32>,
    temps_pad: Vec<f32>,
}

impl MemIntensive {
    pub fn new(
        compute: Compute,
        sensors: usize,
        window_micros: u64,
        slide_micros: u64,
        start_micros: u64,
    ) -> Self {
        // The AOT artifacts carry K = 1024 key slots; wider configurations
        // use the native path for state (documented in DESIGN.md §5).
        let keys = match &compute {
            Compute::Hlo(_) => sensors.min(HLO_KEYS),
            Compute::Native => sensors,
        };
        Self {
            compute,
            window: SlidingWindow::new(keys, window_micros, slide_micros, start_micros),
            keys,
            stats: StepStats::default(),
            ids_pad: Vec::new(),
            temps_pad: Vec::new(),
        }
    }

    /// Accumulate one parsed batch into the open pane.
    fn accumulate(&mut self, batch: &EventBatch) -> Result<(), String> {
        match &self.compute {
            Compute::Hlo(rt) => {
                let mut off = 0;
                while off < batch.len() {
                    let remaining = batch.len() - off;
                    let artifact = rt.select("mem_pipeline_step", remaining)?;
                    let b = artifact.batch;
                    let k = artifact.keys;
                    let name = artifact.name.clone();
                    debug_assert_eq!(k, HLO_KEYS);
                    let take = b.min(remaining);
                    self.ids_pad.clear();
                    self.temps_pad.clear();
                    for i in off..off + take {
                        // Out-of-range sensors (> K) become padding too.
                        let id = batch.ids[i] as usize;
                        self.ids_pad
                            .push(if id < self.keys { id as i32 } else { k as i32 });
                        self.temps_pad.push(batch.temps[i]);
                    }
                    // Pad with id == K so padded slots drop out of the
                    // one-hot mask inside the kernel.
                    self.ids_pad.resize(b, k as i32);
                    self.temps_pad.resize(b, 0.0);
                    // HLO state width is K; pane state is self.keys <= K.
                    let pane = self.window.current_pane();
                    let mut sum_state = pane.sum.clone();
                    let mut cnt_state = pane.cnt.clone();
                    sum_state.resize(k, 0.0);
                    cnt_state.resize(k, 0.0);
                    let out = rt.execute_f32(
                        &name,
                        &[
                            Input::I32(&self.ids_pad),
                            Input::F32(&self.temps_pad),
                            Input::F32(&sum_state),
                            Input::F32(&cnt_state),
                        ],
                    )?;
                    self.stats.hlo_calls += 1;
                    let mut it = out.into_iter();
                    let mut new_sum = it.next().ok_or("missing sum output")?;
                    let mut new_cnt = it.next().ok_or("missing cnt output")?;
                    new_sum.truncate(self.keys);
                    new_cnt.truncate(self.keys);
                    self.window.store_state(new_sum, new_cnt);
                    off += take;
                }
                Ok(())
            }
            Compute::Native => {
                self.window.accumulate_native(&batch.ids, &batch.temps);
                Ok(())
            }
        }
    }

    /// Serialize window emissions as compact JSON aggregate records.
    fn emit(&mut self, emits: Vec<WindowEmit>, out: &mut Vec<Record>) {
        for e in emits {
            self.stats.window_emits += 1;
            for &(key, mean, count) in &e.aggregates {
                let payload = format!(
                    "{{\"win\":{},\"id\":{},\"avg\":{:.3},\"n\":{}}}",
                    e.end_micros, key, mean, count
                );
                out.push(Record::new(key, payload.into_bytes(), e.end_micros));
                self.stats.events_out += 1;
            }
        }
    }
}

impl PipelineStep for MemIntensive {
    fn name(&self) -> &str {
        "mem"
    }

    fn process(
        &mut self,
        now_micros: u64,
        _records: &[Record],
        batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        if !batch.is_empty() {
            self.stats.events_in += batch.len() as u64;
            self.accumulate(batch)?;
        }
        let emits = self.window.advance(now_micros);
        self.emit(emits, out);
        Ok(())
    }

    fn finish(&mut self, now_micros: u64, out: &mut Vec<Record>) -> Result<(), String> {
        // Drain boundaries reached by `now`, then force the final pane
        // closed so short runs still emit their window.
        let mut emits = self.window.advance(now_micros);
        emits.extend(self.window.flush());
        self.emit(emits, out);
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RuntimeFactory;
    use crate::util::json;

    fn batch(ids: &[u32], temps: &[f32], ts: u64) -> EventBatch {
        EventBatch {
            ids: ids.to_vec(),
            temps: temps.to_vec(),
            gen_ts: vec![ts; ids.len()],
            append_ts: vec![ts; ids.len()],
            payload_bytes: ids.len() as u64 * 27,
        }
    }

    #[test]
    fn native_window_emits_per_key_means() {
        let mut p = MemIntensive::new(Compute::Native, 16, 10_000_000, 2_000_000, 0);
        let mut out = Vec::new();
        p.process(0, &[], &batch(&[1, 1, 2], &[10.0, 20.0, 7.0], 0), &mut out)
            .unwrap();
        assert!(out.is_empty(), "no boundary crossed yet");
        p.process(2_000_000, &[], &EventBatch::default(), &mut out)
            .unwrap();
        assert_eq!(out.len(), 2);
        let agg = json::parse(std::str::from_utf8(out[0].payload()).unwrap()).unwrap();
        assert_eq!(agg.get("id").unwrap().as_i64(), Some(1));
        assert!((agg.get("avg").unwrap().as_f64().unwrap() - 15.0).abs() < 1e-6);
        assert_eq!(agg.get("n").unwrap().as_i64(), Some(2));
        assert_eq!(p.stats().window_emits, 1);
    }

    #[test]
    fn hlo_state_update_matches_native() {
        let f = RuntimeFactory::default_dir();
        if !f.available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut native = MemIntensive::new(Compute::Native, 64, 4_000_000, 2_000_000, 0);
        let mut hlo = MemIntensive::new(
            Compute::Hlo(f.create().unwrap()),
            64,
            4_000_000,
            2_000_000,
            0,
        );
        let ids: Vec<u32> = (0..500).map(|i| i % 64).collect();
        let temps: Vec<f32> = (0..500).map(|i| (i as f32) / 10.0).collect();
        let (mut on, mut oh) = (Vec::new(), Vec::new());
        native.process(0, &[], &batch(&ids, &temps, 0), &mut on).unwrap();
        hlo.process(0, &[], &batch(&ids, &temps, 0), &mut oh).unwrap();
        native.process(2_000_000, &[], &EventBatch::default(), &mut on).unwrap();
        hlo.process(2_000_000, &[], &EventBatch::default(), &mut oh).unwrap();
        assert_eq!(on.len(), oh.len());
        assert_eq!(on.len(), 64);
        for (a, b) in on.iter().zip(&oh) {
            let ja = json::parse(std::str::from_utf8(a.payload()).unwrap()).unwrap();
            let jb = json::parse(std::str::from_utf8(b.payload()).unwrap()).unwrap();
            assert_eq!(ja.get("id"), jb.get("id"));
            let va = ja.get("avg").unwrap().as_f64().unwrap();
            let vb = jb.get("avg").unwrap().as_f64().unwrap();
            assert!((va - vb).abs() < 0.01, "{va} vs {vb}");
            assert_eq!(ja.get("n"), jb.get("n"));
        }
        assert!(hlo.stats().hlo_calls >= 1);
    }

    #[test]
    fn finish_flushes_pending_pane() {
        let mut p = MemIntensive::new(Compute::Native, 8, 2_000_000, 1_000_000, 0);
        let mut out = Vec::new();
        p.process(100, &[], &batch(&[3], &[5.0], 100), &mut out).unwrap();
        assert!(out.is_empty());
        p.finish(1_000_000, &mut out).unwrap();
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn out_of_range_sensor_ids_do_not_poison_state() {
        let mut p = MemIntensive::new(Compute::Native, 4, 2_000_000, 1_000_000, 0);
        let mut out = Vec::new();
        p.process(0, &[], &batch(&[2, 9999], &[1.0, 1.0], 0), &mut out)
            .unwrap();
        p.finish(1_000_000, &mut out).unwrap();
        assert_eq!(out.len(), 1, "only the in-range key emits");
    }
}
