//! Pass-through pipeline: the baseline (paper Sec. 3.3, green path).
//!
//! "The generated data is transmitted through the message broker, ingested
//! by the streaming engines, and then forwarded to the message broker
//! without undergoing any processing."  Payload `Arc`s are forwarded, so
//! the cost is purely the engine's plumbing — which is the point of the
//! baseline.
//!
//! Since the operator-chain redesign the production path is the canonical
//! `[forward]` chain; this struct is the reference implementation the
//! equivalence suite compares against.

use super::{PipelineStep, StepStats};
use crate::broker::Record;
use crate::engine::EventBatch;

#[derive(Default)]
pub struct PassThrough {
    stats: StepStats,
}

impl PassThrough {
    pub fn new() -> Self {
        Self::default()
    }
}

impl PipelineStep for PassThrough {
    fn name(&self) -> &str {
        "passthrough"
    }

    fn needs_parse(&self) -> bool {
        false
    }

    fn process(
        &mut self,
        _now_micros: u64,
        records: &[Record],
        _batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.stats.events_in += records.len() as u64;
        self.stats.events_out += records.len() as u64;
        out.extend(records.iter().cloned());
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forwards_without_copying_payloads() {
        let mut p = PassThrough::new();
        let records = vec![
            Record::new(1, vec![1u8, 2, 3], 10),
            Record::new(2, vec![4u8, 5], 20),
        ];
        let mut out = Vec::new();
        p.process(0, &records, &EventBatch::default(), &mut out)
            .unwrap();
        assert_eq!(out.len(), 2);
        assert!(out[0].shares_storage_with(&records[0]));
        let s = p.stats();
        assert_eq!(s.events_in, 2);
        assert_eq!(s.events_out, 2);
    }

    #[test]
    fn does_not_require_parsing() {
        assert!(!PassThrough::new().needs_parse());
    }
}
