//! The paper's processing pipelines (Sec. 3.3): pass-through,
//! CPU-intensive, memory-intensive — plus the fused extension.
//!
//! Every pipeline implements [`PipelineStep`]; the compute-heavy ones run
//! their per-batch math either through the AOT HLO artifacts
//! ([`Compute::Hlo`], the default — L1/L2 of the stack) or through native
//! Rust reference ops ([`Compute::Native`], the ablation baseline and the
//! fallback when artifacts are absent).
//!
//! Pipeline steps are **thread-confined** (they own a PJRT [`Runtime`])
//! and are created inside each engine task thread via [`StepFactory`].

pub mod cpu;
pub mod fused;
pub mod mem;
pub mod passthrough;

pub use cpu::CpuIntensive;
pub use fused::Fused;
pub use mem::MemIntensive;
pub use passthrough::PassThrough;

use crate::broker::Record;
use crate::config::{BenchConfig, PipelineKind};
use crate::engine::EventBatch;
use crate::runtime::{Runtime, RuntimeFactory};

/// Cumulative per-step statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepStats {
    pub events_in: u64,
    pub events_out: u64,
    pub alerts: u64,
    pub hlo_calls: u64,
    pub window_emits: u64,
    pub parse_failures: u64,
}

/// One pipeline instance, owned by one engine task thread.
pub trait PipelineStep {
    fn name(&self) -> &'static str;

    /// Whether the task must parse records into an [`EventBatch`]
    /// (pass-through forwards raw payloads and skips parsing).
    fn needs_parse(&self) -> bool {
        true
    }

    /// Process one batch.  Exactly one of the two input views is
    /// populated: when `needs_parse()` is true the task parses straight
    /// from the broker's batch views into `batch` and `records` is empty;
    /// when it is false, `records` holds the raw broker records
    /// (materialized compatibility views sharing the batch arenas) and
    /// `batch` is empty.  Outputs are pushed into `out` for the egestion
    /// topic.
    fn process(
        &mut self,
        now_micros: u64,
        records: &[Record],
        batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String>;

    /// End-of-stream flush (windows emit their pending aggregates).
    fn finish(&mut self, _now_micros: u64, _out: &mut Vec<Record>) -> Result<(), String> {
        Ok(())
    }

    fn stats(&self) -> StepStats;
}

/// Compute backend for the heavy pipelines.
pub enum Compute {
    /// AOT HLO artifacts executed via PJRT (the three-layer path).
    Hlo(Runtime),
    /// Native Rust reference implementation (ablation baseline).
    Native,
}

impl Compute {
    pub fn label(&self) -> &'static str {
        match self {
            Compute::Hlo(_) => "hlo",
            Compute::Native => "native",
        }
    }
}

/// Builder signature for user-defined pipelines (paper Sec. 3.3: "users
/// can also define custom processing logic … with minimal modifications").
/// Called once per engine task thread with the task's start time.
pub type CustomStepBuilder =
    Box<dyn Fn(u64) -> Result<Box<dyn PipelineStep>, String> + Send + Sync>;

/// Sendable factory: builds a fresh thread-confined step per engine task.
pub struct StepFactory {
    config: BenchConfig,
    runtime_factory: Option<RuntimeFactory>,
    custom: Option<CustomStepBuilder>,
}

impl StepFactory {
    /// `runtime_factory = None` (or `use_hlo: false` in the config) forces
    /// the native compute path.
    pub fn new(config: &BenchConfig, runtime_factory: Option<RuntimeFactory>) -> Self {
        Self {
            config: config.clone(),
            runtime_factory: if config.engine.use_hlo {
                runtime_factory
            } else {
                None
            },
            custom: None,
        }
    }

    /// A factory that builds user-defined pipeline steps instead of the
    /// configured kind — the suite's extensibility hook (see
    /// `examples/custom_pipeline.rs`).
    pub fn custom(config: &BenchConfig, builder: CustomStepBuilder) -> Self {
        Self {
            config: config.clone(),
            runtime_factory: None,
            custom: Some(builder),
        }
    }

    fn compute(&self, program: &str) -> Result<Compute, String> {
        match &self.runtime_factory {
            Some(f) if f.available() => {
                let rt = f.create()?;
                // Compile every batch-size variant up front: PJRT
                // compilation must never land on the first hot batch
                // (it would poison the latency tail).
                rt.warm(program)?;
                Ok(Compute::Hlo(rt))
            }
            Some(f) => Err(format!(
                "artifacts not found in {} — run `make artifacts`",
                f.dir().display()
            )),
            None => Ok(Compute::Native),
        }
    }

    /// Build the configured pipeline for one task thread.
    pub fn create(&self, start_micros: u64) -> Result<Box<dyn PipelineStep>, String> {
        if let Some(builder) = &self.custom {
            return builder(start_micros);
        }
        let c = &self.config;
        Ok(match c.engine.pipeline {
            PipelineKind::PassThrough => Box::new(PassThrough::new()),
            PipelineKind::CpuIntensive => Box::new(CpuIntensive::new(
                self.compute("cpu_pipeline_step")?,
                c.engine.threshold_f,
                c.workload.event_bytes,
            )),
            PipelineKind::MemIntensive => Box::new(MemIntensive::new(
                self.compute("mem_pipeline_step")?,
                c.workload.sensors as usize,
                c.engine.window_micros,
                c.engine.slide_micros,
                start_micros,
            )),
            PipelineKind::Fused => Box::new(Fused::new(
                self.compute("fused_pipeline_step")?,
                c.engine.threshold_f,
                c.workload.event_bytes,
                c.workload.sensors as usize,
                c.engine.window_micros,
                c.engine.slide_micros,
                start_micros,
            )),
        })
    }
}

/// Round `n` up to the HLO key-state width supported by the artifacts.
/// The AOT variants are built with K = 1024; configs with more sensors
/// fall back to native compute for the keyed pipelines.
pub const HLO_KEYS: usize = 1024;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_builds_each_kind_native() {
        let mut cfg = BenchConfig::default();
        cfg.engine.use_hlo = false;
        for kind in [
            PipelineKind::PassThrough,
            PipelineKind::CpuIntensive,
            PipelineKind::MemIntensive,
            PipelineKind::Fused,
        ] {
            cfg.engine.pipeline = kind;
            let f = StepFactory::new(&cfg, None);
            let step = f.create(0).unwrap();
            assert_eq!(step.name(), kind.name());
        }
    }

    #[test]
    fn missing_artifacts_is_a_readable_error() {
        let mut cfg = BenchConfig::default();
        cfg.engine.pipeline = PipelineKind::CpuIntensive;
        let f = StepFactory::new(&cfg, Some(RuntimeFactory::new("/nonexistent")));
        let err = f.create(0).err().unwrap();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
