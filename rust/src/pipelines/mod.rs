//! Processing pipelines: the composable operator-chain API plus the
//! paper's reference pipelines (Sec. 3.3).
//!
//! The engine-facing contract is [`PipelineStep`]; since the operator-chain
//! redesign its production implementation is [`Chain`] — a sequence of
//! [`Operator`]s ([`operator`]) compiled from a declarative
//! [`PipelineSpec`](crate::config::PipelineSpec) by [`StepFactory`].  The
//! four paper pipelines (pass-through, CPU-, memory-intensive, fused) are
//! canonical chains; the monolithic structs ([`PassThrough`],
//! [`CpuIntensive`], [`MemIntensive`], [`Fused`]) remain as the reference
//! implementations the equivalence suite (`rust/tests/chain_equivalence.rs`)
//! and the fused-dispatch ablation compare against.
//!
//! Compute-heavy operators run their per-batch math either through the AOT
//! HLO artifacts ([`Compute::Hlo`] / [`operator::OpCompute::Hlo`], the
//! default — L1/L2 of the stack) or through native Rust reference ops (the
//! ablation baseline and the fallback when artifacts are absent).
//!
//! Pipeline steps are **thread-confined** (they may own a PJRT
//! [`Runtime`]) and are created inside each engine task thread via
//! [`StepFactory`]; user operators plug in through [`OperatorRegistry`].

pub mod cpu;
pub mod fused;
pub mod mem;
pub mod operator;
pub mod passthrough;
pub mod registry;
pub mod staged;

pub use cpu::CpuIntensive;
pub use fused::Fused;
pub use mem::MemIntensive;
pub use operator::{Chain, OpCompute, Operator, RowBatch};
pub use passthrough::PassThrough;
pub use registry::{OpContext, OperatorBuilder, OperatorRegistry};
pub use staged::{LockstepExchange, StagedChain};

use std::sync::Arc;

use crate::broker::Record;
use crate::config::{BenchConfig, ExchangeMode, StageSpec};
use crate::engine::exchange::ExchangeFabric;
use crate::engine::EventBatch;
use crate::runtime::{Runtime, RuntimeFactory};
use crate::util::json::Json;

/// Cumulative per-step statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepStats {
    pub events_in: u64,
    pub events_out: u64,
    pub alerts: u64,
    pub hlo_calls: u64,
    pub window_emits: u64,
    pub parse_failures: u64,
    /// Event-time records that arrived behind the watermark but were
    /// merged or side-counted (see [`crate::engine::LatePolicy`]).
    pub late_events: u64,
    /// Event-time records discarded: too late for every covering window,
    /// or late under the `drop` policy.
    pub dropped_events: u64,
    /// Maximum observed watermark lag (processing time − watermark), µs.
    /// Merged with `max`, not summed.
    pub watermark_lag_micros: u64,
    /// Rows routed through a keyed-exchange boundary (the shuffle plane);
    /// zero for chains without an exchange.
    pub exchange_records: u64,
    /// Bytes moved across exchange boundaries (row wire size × records).
    pub exchange_bytes: u64,
    /// Maximum observed exchange queue residency (send → drain), µs.
    /// Merged with `max`, not summed.
    pub exchange_wait_micros: u64,
    /// Aligned checkpoints this task contributed a snapshot to; zero when
    /// checkpointing is disabled.
    pub checkpoints: u64,
    /// Serialized bytes written into committed checkpoint files.
    pub checkpoint_bytes: u64,
    /// Time spent snapshotting state and writing checkpoint files, µs.
    pub checkpoint_time_micros: u64,
}

impl StepStats {
    /// Fold `other` into `self` (aggregating one operator's stats across
    /// engine tasks for the run report).  Counters sum; the watermark lag
    /// keeps the worst (maximum) across tasks.
    pub fn merge(&mut self, other: &StepStats) {
        self.events_in += other.events_in;
        self.events_out += other.events_out;
        self.alerts += other.alerts;
        self.hlo_calls += other.hlo_calls;
        self.window_emits += other.window_emits;
        self.parse_failures += other.parse_failures;
        self.late_events += other.late_events;
        self.dropped_events += other.dropped_events;
        self.watermark_lag_micros = self.watermark_lag_micros.max(other.watermark_lag_micros);
        self.exchange_records += other.exchange_records;
        self.exchange_bytes += other.exchange_bytes;
        self.exchange_wait_micros = self.exchange_wait_micros.max(other.exchange_wait_micros);
        self.checkpoints += other.checkpoints;
        self.checkpoint_bytes += other.checkpoint_bytes;
        self.checkpoint_time_micros += other.checkpoint_time_micros;
    }

    /// JSON object for results/report documents.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("events_in", Json::Int(self.events_in as i64));
        j.set("events_out", Json::Int(self.events_out as i64));
        j.set("alerts", Json::Int(self.alerts as i64));
        j.set("hlo_calls", Json::Int(self.hlo_calls as i64));
        j.set("window_emits", Json::Int(self.window_emits as i64));
        j.set("parse_failures", Json::Int(self.parse_failures as i64));
        j.set("late_events", Json::Int(self.late_events as i64));
        j.set("dropped_events", Json::Int(self.dropped_events as i64));
        j.set(
            "watermark_lag_us",
            Json::Int(self.watermark_lag_micros as i64),
        );
        j.set("exchange_records", Json::Int(self.exchange_records as i64));
        j.set("exchange_bytes", Json::Int(self.exchange_bytes as i64));
        j.set(
            "exchange_wait_us",
            Json::Int(self.exchange_wait_micros as i64),
        );
        j.set("checkpoints", Json::Int(self.checkpoints as i64));
        j.set("checkpoint_bytes", Json::Int(self.checkpoint_bytes as i64));
        j.set(
            "checkpoint_time_us",
            Json::Int(self.checkpoint_time_micros as i64),
        );
        j
    }

    /// Parse back what [`StepStats::to_json`] wrote (missing fields read
    /// as 0, so older report documents stay loadable).
    pub fn from_json(j: &Json) -> StepStats {
        let int = |key: &str| j.get(key).and_then(|v| v.as_i64()).unwrap_or(0).max(0) as u64;
        StepStats {
            events_in: int("events_in"),
            events_out: int("events_out"),
            alerts: int("alerts"),
            hlo_calls: int("hlo_calls"),
            window_emits: int("window_emits"),
            parse_failures: int("parse_failures"),
            late_events: int("late_events"),
            dropped_events: int("dropped_events"),
            watermark_lag_micros: int("watermark_lag_us"),
            exchange_records: int("exchange_records"),
            exchange_bytes: int("exchange_bytes"),
            exchange_wait_micros: int("exchange_wait_us"),
            checkpoints: int("checkpoints"),
            checkpoint_bytes: int("checkpoint_bytes"),
            checkpoint_time_micros: int("checkpoint_time_us"),
        }
    }
}

/// One pipeline instance, owned by one engine task thread.
pub trait PipelineStep {
    fn name(&self) -> &str;

    /// Whether the task must parse records into an [`EventBatch`]
    /// (pass-through forwards raw payloads and skips parsing).
    fn needs_parse(&self) -> bool {
        true
    }

    /// Process one batch.  Exactly one of the two input views is
    /// populated: when `needs_parse()` is true the task parses straight
    /// from the broker's batch views into `batch` and `records` is empty;
    /// when it is false, `records` holds the raw broker records
    /// (materialized compatibility views sharing the batch arenas) and
    /// `batch` is empty.  Outputs are pushed into `out` for the egestion
    /// topic.
    fn process(
        &mut self,
        now_micros: u64,
        records: &[Record],
        batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String>;

    /// End-of-stream flush (windows emit their pending aggregates).
    fn finish(&mut self, _now_micros: u64, _out: &mut Vec<Record>) -> Result<(), String> {
        Ok(())
    }

    /// Periodic tick while the task has nothing polled.  Exchange-staged
    /// chains drain their inbound boundaries and keep frontiers moving so
    /// a quiet broker partition never stalls downstream watermarks; plain
    /// chains do nothing.
    fn idle(&mut self, _now_micros: u64, _out: &mut Vec<Record>) -> Result<(), String> {
        Ok(())
    }

    /// The task is abandoning this step after an error: release anything
    /// peers are waiting on.  Exchange-staged chains mark themselves done
    /// on every boundary so sibling tasks' finish drains terminate
    /// instead of waiting forever on a dead upstream; plain chains do
    /// nothing.
    fn abort(&mut self) {}

    fn stats(&self) -> StepStats;

    /// Per-operator stats for the run report; monolithic steps report one
    /// entry, [`Chain`] one per operator in chain order.
    fn operator_stats(&self) -> Vec<(String, StepStats)> {
        vec![(self.name().to_string(), self.stats())]
    }

    /// Serialize the step's operator state for an aligned checkpoint.
    /// [`Chain`] and [`StagedChain`] support this; steps that don't (the
    /// monolithic reference pipelines, custom steps) return a readable
    /// error, which config validation surfaces before any run starts.
    fn snapshot(&self) -> Result<Json, String> {
        Err(format!(
            "pipeline step '{}' does not support checkpointing",
            self.name()
        ))
    }

    /// Restore state captured by [`PipelineStep::snapshot`] into a freshly
    /// built step of the same configuration.
    fn restore(&mut self, _state: &Json) -> Result<(), String> {
        Err(format!(
            "pipeline step '{}' does not support checkpointing",
            self.name()
        ))
    }
}

/// Compute backend for the monolithic reference pipelines.
pub enum Compute {
    /// AOT HLO artifacts executed via PJRT (the three-layer path).
    Hlo(Runtime),
    /// Native Rust reference implementation (ablation baseline).
    Native,
}

impl Compute {
    pub fn label(&self) -> &'static str {
        match self {
            Compute::Hlo(_) => "hlo",
            Compute::Native => "native",
        }
    }
}

/// Builder signature for fully custom pipeline steps — the pre-redesign
/// extensibility hook, kept for steps that want to bypass the operator
/// chain entirely.  Prefer [`OperatorRegistry`] + a `pipeline: {ops: ...}`
/// spec for composable custom logic.
/// Called once per engine task thread with the task's start time.
pub type CustomStepBuilder =
    Box<dyn Fn(u64) -> Result<Box<dyn PipelineStep>, String> + Send + Sync>;

/// Sendable factory: builds a fresh thread-confined step per engine task.
///
/// Since the operator-chain redesign this is a thin spec→chain compiler:
/// the configured [`PipelineSpec`](crate::config::PipelineSpec) (explicit
/// `pipeline: {ops: [...]}`, or the canonical chain of the configured
/// [`PipelineKind`](crate::config::PipelineKind)) is compiled into a
/// [`Chain`] on each task thread.
pub struct StepFactory {
    config: BenchConfig,
    runtime_factory: Option<RuntimeFactory>,
    custom: Option<CustomStepBuilder>,
    registry: Option<Arc<OperatorRegistry>>,
}

impl StepFactory {
    /// `runtime_factory = None` (or `use_hlo: false` in the config) forces
    /// the native compute path.
    pub fn new(config: &BenchConfig, runtime_factory: Option<RuntimeFactory>) -> Self {
        Self {
            config: config.clone(),
            runtime_factory: if config.engine.use_hlo {
                runtime_factory
            } else {
                None
            },
            custom: None,
            registry: None,
        }
    }

    /// A factory whose chains can resolve user operators by name — the
    /// suite's extensibility hook (see `examples/custom_pipeline.rs`).
    pub fn with_registry(
        config: &BenchConfig,
        runtime_factory: Option<RuntimeFactory>,
        registry: Arc<OperatorRegistry>,
    ) -> Self {
        let mut f = Self::new(config, runtime_factory);
        f.registry = Some(registry);
        f
    }

    /// A factory that builds user-defined pipeline steps instead of the
    /// configured kind, bypassing the chain compiler entirely.
    pub fn custom(config: &BenchConfig, builder: CustomStepBuilder) -> Self {
        Self {
            config: config.clone(),
            runtime_factory: None,
            custom: Some(builder),
            registry: None,
        }
    }

    /// Build the configured pipeline for one task thread.
    pub fn create(&self, start_micros: u64) -> Result<Box<dyn PipelineStep>, String> {
        if let Some(builder) = &self.custom {
            return builder(start_micros);
        }
        let spec = self.config.engine.effective_spec();
        let label = self.config.engine.pipeline_label();
        let chain = Chain::compile(
            &self.config,
            &spec,
            label,
            self.runtime_factory.as_ref(),
            self.registry.as_deref(),
            start_micros,
        )?;
        Ok(Box::new(chain))
    }

    /// The stage decomposition the engine should build an exchange fabric
    /// for: `Some` exactly when the configured chain splits at a keyed
    /// boundary, the exchange is enabled, and no custom builder bypasses
    /// the chain compiler.
    pub fn staged_spec(&self) -> Option<Vec<StageSpec>> {
        if self.custom.is_some() || self.config.engine.exchange == ExchangeMode::None {
            return None;
        }
        let stages = self
            .config
            .engine
            .effective_spec()
            .split_stages(self.config.engine.parallelism);
        (stages.len() > 1).then_some(stages)
    }

    /// Build one task's exchange-staged step over a shared fabric (built
    /// from this factory's [`StepFactory::staged_spec`]).
    pub fn create_staged(
        &self,
        task_id: u32,
        fabric: &Arc<ExchangeFabric>,
        start_micros: u64,
    ) -> Result<Box<dyn PipelineStep>, String> {
        let stages = self
            .staged_spec()
            .ok_or("create_staged called on a factory whose spec does not stage")?;
        let staged = StagedChain::compile(
            &self.config,
            &stages,
            self.config.engine.pipeline_label(),
            task_id,
            fabric.clone(),
            self.runtime_factory.as_ref(),
            self.registry.as_deref(),
            start_micros,
        )?;
        Ok(Box::new(staged))
    }
}

/// Round `n` up to the HLO key-state width supported by the artifacts.
/// The AOT variants are built with K = 1024; configs with more sensors
/// fall back to native compute for the keyed pipelines.
pub const HLO_KEYS: usize = 1024;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineKind;

    #[test]
    fn factory_builds_each_kind_native() {
        let mut cfg = BenchConfig::default();
        cfg.engine.use_hlo = false;
        for kind in [
            PipelineKind::PassThrough,
            PipelineKind::CpuIntensive,
            PipelineKind::MemIntensive,
            PipelineKind::Fused,
        ] {
            cfg.engine.pipeline = kind;
            let f = StepFactory::new(&cfg, None);
            let step = f.create(0).unwrap();
            assert_eq!(step.name(), kind.name());
        }
    }

    #[test]
    fn missing_artifacts_is_a_readable_error() {
        let mut cfg = BenchConfig::default();
        cfg.engine.pipeline = PipelineKind::CpuIntensive;
        let f = StepFactory::new(&cfg, Some(RuntimeFactory::new("/nonexistent")));
        let err = f.create(0).err().unwrap();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn passthrough_never_needs_artifacts() {
        let mut cfg = BenchConfig::default();
        cfg.engine.pipeline = PipelineKind::PassThrough;
        let f = StepFactory::new(&cfg, Some(RuntimeFactory::new("/nonexistent")));
        let step = f.create(0).unwrap();
        assert!(!step.needs_parse());
    }

    #[test]
    fn factory_compiles_explicit_specs_into_chains() {
        use crate::config::{CmpOp, OpSpec, PipelineSpec};
        let mut cfg = BenchConfig::default();
        cfg.engine.use_hlo = false;
        cfg.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![
                OpSpec::Filter {
                    cmp: CmpOp::Gt,
                    value: 0.0,
                },
                OpSpec::EmitEvents,
            ],
        });
        let f = StepFactory::new(&cfg, None);
        let step = f.create(0).unwrap();
        assert_eq!(step.name(), "chain[filter→emit_events]");
        assert_eq!(step.operator_stats().len(), 2);
    }

    #[test]
    fn step_stats_merge_and_json_roundtrip() {
        let mut a = StepStats {
            events_in: 10,
            events_out: 8,
            alerts: 2,
            hlo_calls: 1,
            window_emits: 0,
            parse_failures: 1,
            late_events: 4,
            dropped_events: 2,
            watermark_lag_micros: 900,
            exchange_records: 40,
            exchange_bytes: 960,
            exchange_wait_micros: 70,
            checkpoints: 2,
            checkpoint_bytes: 4_096,
            checkpoint_time_micros: 350,
        };
        let b = StepStats {
            events_in: 5,
            events_out: 5,
            alerts: 1,
            hlo_calls: 0,
            window_emits: 3,
            parse_failures: 0,
            late_events: 1,
            dropped_events: 0,
            watermark_lag_micros: 1_500,
            exchange_records: 10,
            exchange_bytes: 240,
            exchange_wait_micros: 30,
            checkpoints: 1,
            checkpoint_bytes: 1_024,
            checkpoint_time_micros: 150,
        };
        a.merge(&b);
        assert_eq!(a.events_in, 15);
        assert_eq!(a.events_out, 13);
        assert_eq!(a.alerts, 3);
        assert_eq!(a.window_emits, 3);
        assert_eq!(a.late_events, 5);
        assert_eq!(a.dropped_events, 2);
        assert_eq!(a.watermark_lag_micros, 1_500, "lag merges with max, not sum");
        assert_eq!(a.exchange_records, 50);
        assert_eq!(a.exchange_bytes, 1_200);
        assert_eq!(a.exchange_wait_micros, 70, "queue wait merges with max");
        assert_eq!(a.checkpoints, 3);
        assert_eq!(a.checkpoint_bytes, 5_120);
        assert_eq!(a.checkpoint_time_micros, 500);
        assert_eq!(StepStats::from_json(&a.to_json()), a);
        // Missing fields read as zero (older documents).
        assert_eq!(StepStats::from_json(&Json::obj()), StepStats::default());
    }
}
