//! Exchange-staged pipeline execution: one [`StagedChain`] per engine
//! task, connected through the [`ExchangeFabric`].
//!
//! A chain with `keyby` boundaries is split into
//! [`StageSpec`](crate::config::StageSpec)s; every task hosts an instance
//! of each stage it is a member of (task id < stage parallelism) and the
//! fabric hash-routes rows between them with the broker's Fibonacci hash.
//! Three mechanisms make the results invariant under
//! `engine.parallelism`:
//!
//! * **Key routing** — after a re-keying, every row of a derived key
//!   group lands on the same stage instance, so keyed window state sees
//!   whole groups instead of the task-local slices the pre-exchange
//!   engine aggregated.
//! * **Watermark min-merge** — event-time stages advance their watermark
//!   from the boundary's safe frontier (minimum over live upstream
//!   frontiers), never from locally observed rows; a fast sub-stream
//!   cannot finalize windows whose rows are still queued on a slower
//!   upstream path.
//! * **Completeness gating** — a global `topk` stage buffers aggregate
//!   rows until the safe frontier passes their window end, then releases
//!   them in a canonical `(ts, key)` order: the selection always sees
//!   complete windows, in a deterministic sequence.
//!
//! [`LockstepExchange`] drives a whole staged pipeline single-threaded in
//! deterministic rounds — the harness behind
//! `rust/tests/shuffle_equivalence.rs` and the `hotpath_micro` shuffle
//! case.

use std::sync::Arc;

use super::operator::Chain;
use super::{OperatorRegistry, PipelineStep, StepStats};
use crate::broker::{fib_slot, Record};
use crate::config::{BenchConfig, ExchangeMode, PipelineSpec, StageSpec};
use crate::engine::exchange::{ExchangeFabric, ExchangePacket, ROW_WIRE_BYTES};
use crate::engine::EventBatch;
use crate::pipelines::RowBatch;
use crate::runtime::RuntimeFactory;
use crate::util::json::Json;

/// Per-channel queue depth (packets, not rows): one packet is one routed
/// slice per (call, destination), so a few thousand absorbs long stalls
/// while `try_send` still delivers backpressure eventually.
const CHANNEL_PACKETS: usize = 4096;

/// Per-stage cap on packets stashed off the channel during send relief.
/// Relief must drain *something* to break sender cycles, but an
/// unbounded stash would convert inbound backpressure into unbounded
/// memory during a long stall; past the cap, backpressure propagates
/// upstream again (worst case the 30s send deadline fails the run —
/// a bounded error beats an OOM).
const STASH_CAP_PACKETS: usize = 4 * CHANNEL_PACKETS;

/// Completeness gate: holds rows until the boundary's safe frontier
/// passes their timestamp, then releases them sorted by
/// `(ts, key, value bits, count)` — a total, content-only order, so the
/// release sequence is identical at every parallelism.
#[derive(Default)]
struct Gate {
    pending: Vec<(u64, u32, u32, u64)>,
}

impl Gate {
    fn absorb(&mut self, rows: &RowBatch) {
        for i in 0..rows.len() {
            self.pending
                .push((rows.ts[i], rows.keys[i], rows.vals[i].to_bits(), rows.counts[i]));
        }
    }

    fn release_into(&mut self, safe_micros: u64, out: &mut RowBatch) {
        if self.pending.is_empty() {
            return;
        }
        let mut released = Vec::new();
        self.pending.retain(|r| {
            if r.0 <= safe_micros {
                released.push(*r);
                false
            } else {
                true
            }
        });
        released.sort_unstable();
        for (ts, key, bits, count) in released {
            out.push(key, f32::from_bits(bits), ts, count);
        }
    }

    fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }
}

/// One task's slot for one stage.
struct StageSlot {
    /// Compiled chain when this task hosts an instance (`task_id <
    /// stage.parallelism`); `None` otherwise.
    chain: Option<Chain>,
    /// Op names for the run report when the stage is not hosted here.
    op_names: Vec<String>,
    /// Completeness gate on the inbound boundary (top-k stages only).
    gate: Gate,
    gated: bool,
    /// Reused working set for the stage's inbound rows.
    rows: RowBatch,
    /// Packets pulled off the inbound channel while this task was
    /// waiting on a full outbound queue (`send_with_relief`): moved out
    /// of the channel to free capacity, consumed by the next `pump`.
    stash: Vec<ExchangePacket>,
    finished: bool,
}

/// The staged, exchange-connected [`PipelineStep`] one engine task runs.
pub struct StagedChain {
    label: String,
    task_id: u32,
    fabric: Arc<ExchangeFabric>,
    stages: Vec<StageSlot>,
    /// Highest generation timestamp seen at the source (stage 0 input).
    src_frontier: u64,
    /// Liveness slack subtracted from `now` for the source frontier: the
    /// largest event-time watermark bound in the spec (0 for pure
    /// processing-time chains, where the frontier rides `now`).
    source_slack_micros: u64,
    source_finished: bool,
    /// Stage-0 working set (reused across polls).
    rows: RowBatch,
    /// Per-destination routing scratch.
    route: Vec<RowBatch>,
    /// Drain scratch.
    drain_buf: Vec<ExchangePacket>,
    /// Per-boundary exchange stats from this task's perspective:
    /// `events_in`/`exchange_records`/`exchange_bytes` count the send
    /// side, `events_out` the drain side, `exchange_wait_micros` the
    /// worst queue residency observed on drain.
    boundary_stats: Vec<StepStats>,
}

impl StagedChain {
    /// Compile one task's staged chain.  `stages` must be the
    /// [`PipelineSpec::split_stages`] decomposition the shared `fabric`
    /// was built from.
    #[allow(clippy::too_many_arguments)]
    pub fn compile(
        cfg: &BenchConfig,
        stages_spec: &[StageSpec],
        label: impl Into<String>,
        task_id: u32,
        fabric: Arc<ExchangeFabric>,
        runtime_factory: Option<&RuntimeFactory>,
        registry: Option<&OperatorRegistry>,
        start_micros: u64,
    ) -> Result<StagedChain, String> {
        if stages_spec.len() < 2 {
            return Err("a staged chain needs at least two stages — use Chain directly".into());
        }
        let label = label.into();
        let mut slots = Vec::with_capacity(stages_spec.len());
        // The aggregator of the last window in *earlier* stages, carried
        // so a downstream `emit_aggregates` keeps its field name.
        let mut carried_agg = None;
        for (s, stage) in stages_spec.iter().enumerate() {
            let sub = PipelineSpec {
                ops: stage.ops.clone(),
            };
            let hosted = task_id < stage.parallelism;
            let chain = if hosted {
                let mut c = Chain::compile_with_agg(
                    cfg,
                    &sub,
                    format!("{label}#{s}"),
                    runtime_factory,
                    registry,
                    start_micros,
                    carried_agg,
                )?;
                if s > 0 {
                    c.mark_exchange_fed();
                }
                Some(c)
            } else {
                None
            };
            let gated = s > 0
                && matches!(stage.ops.first(), Some(crate::config::OpSpec::TopK { .. }));
            slots.push(StageSlot {
                chain,
                op_names: sub.ops.iter().map(|o| o.op_name().to_string()).collect(),
                gate: Gate::default(),
                gated,
                rows: RowBatch::default(),
                stash: Vec::new(),
                finished: false,
            });
            carried_agg = sub.last_window_agg().or(carried_agg);
        }
        // Idle-liveness slack: the largest event-time watermark bound in
        // the spec (same resolution as the windows themselves —
        // OpSpec::event_watermark_bound); 0 for processing-time chains,
        // whose idle frontier rides `now` directly.
        let mut slack = 0u64;
        for stage in stages_spec {
            for op in &stage.ops {
                if let Some(bound) = op.event_watermark_bound(cfg) {
                    slack = slack.max(bound);
                }
            }
        }
        let boundaries = stages_spec.len() - 1;
        Ok(StagedChain {
            label,
            task_id,
            fabric,
            stages: slots,
            src_frontier: 0,
            source_slack_micros: slack,
            source_finished: false,
            rows: RowBatch::default(),
            route: Vec::new(),
            drain_buf: Vec::new(),
            boundary_stats: vec![StepStats::default(); boundaries],
        })
    }

    /// The channel capacity the shared fabric should be built with.
    pub fn channel_capacity() -> usize {
        CHANNEL_PACKETS
    }

    /// Source frontier while the task is *idle* (its own partitions
    /// polled empty): the data frontier, floored at `now − slack` for
    /// liveness.  The floor is safe exactly because idle means nothing
    /// older is queued behind this task — any future row's backdating is
    /// bounded by the disorder lateness, which `slack` covers.  The
    /// *active* path (`run_source`) publishes the data frontier alone:
    /// flooring it at wall time there would let broker queueing delay
    /// masquerade as event-time lateness under backlog.
    fn idle_source_frontier(&self, now_micros: u64) -> u64 {
        self.src_frontier
            .max(now_micros.saturating_sub(self.source_slack_micros))
    }

    /// Pull everything off this task's inbound channels into the
    /// per-stage stashes (no processing): frees channel capacity while
    /// this task is itself blocked on a full outbound queue, so a ring of
    /// mutually-sending tasks can never deadlock.
    fn stash_inbound(&mut self) {
        for s in 1..self.stages.len() {
            if self.stages[s].chain.is_none() {
                continue;
            }
            let room = STASH_CAP_PACKETS.saturating_sub(self.stages[s].stash.len());
            if room == 0 {
                continue;
            }
            self.fabric
                .boundary(s - 1)
                .drain(self.task_id, &mut self.stages[s].stash, room);
        }
    }

    /// Deliver one packet, relieving our own inbound queues while the
    /// destination is full.  Never parks: a blocked blocking-`send` here
    /// would stop this task from draining its own channels (self-route
    /// on a full queue would even self-deadlock).  Bounded so a dead
    /// downstream task fails the run instead of spinning forever.
    fn send_with_relief(
        &mut self,
        b: usize,
        dest: u32,
        mut packet: ExchangePacket,
    ) -> Result<(), String> {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            packet = match self.fabric.boundary(b).try_send(dest, packet) {
                Ok(()) => return Ok(()),
                Err(p) => p,
            };
            if std::time::Instant::now() >= deadline {
                return Err(format!(
                    "task {}: exchange send to stage {} instance {dest} timed out — \
                     the downstream task stalled or died",
                    self.task_id,
                    b + 1
                ));
            }
            self.stash_inbound();
            std::thread::sleep(std::time::Duration::from_micros(50));
        }
    }

    /// Hash-route `rows` into boundary `b` and record the send-side
    /// stats.  `rows` is left empty.
    fn route_to(&mut self, b: usize, rows: &mut RowBatch, now_micros: u64) -> Result<(), String> {
        if rows.is_empty() {
            return Ok(());
        }
        let dests = self.fabric.boundary(b).downstreams();
        {
            let stats = &mut self.boundary_stats[b];
            let n = rows.len() as u64;
            stats.events_in += n;
            stats.exchange_records += n;
            stats.exchange_bytes += n * ROW_WIRE_BYTES;
        }
        if dests == 1 {
            let packet = ExchangePacket {
                rows: std::mem::take(rows),
                sent_micros: now_micros,
            };
            return self.send_with_relief(b, 0, packet);
        }
        if self.route.len() < dests as usize {
            self.route.resize_with(dests as usize, RowBatch::default);
        }
        for i in 0..rows.len() {
            let dest = fib_slot(rows.keys[i], dests) as usize;
            self.route[dest].push(rows.keys[i], rows.vals[i], rows.ts[i], rows.counts[i]);
        }
        rows.clear();
        for dest in 0..dests {
            if self.route[dest as usize].is_empty() {
                continue;
            }
            let packet = ExchangePacket {
                rows: std::mem::take(&mut self.route[dest as usize]),
                sent_micros: now_micros,
            };
            self.send_with_relief(b, dest, packet)?;
        }
        Ok(())
    }

    /// Ingest one parsed poll batch through stage 0, route the survivors
    /// into boundary 0, and publish the source frontier.
    fn run_source(
        &mut self,
        now_micros: u64,
        batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        debug_assert!(!self.source_finished, "process after finish");
        for &t in &batch.gen_ts {
            if t > self.src_frontier {
                self.src_frontier = t;
            }
        }
        let mut rows = std::mem::take(&mut self.rows);
        rows.load_events(batch);
        let mut res = self
            .stages[0]
            .chain
            .as_mut()
            .expect("stage 0 is hosted on every task")
            .process_rows(now_micros, &mut rows, out);
        if res.is_ok() {
            res = self.route_to(0, &mut rows, now_micros);
        }
        if res.is_ok() {
            // Data-driven frontier only (no wall-time floor): rows still
            // queued in the broker behind this poll must keep gating the
            // downstream watermark.  Published only after the rows it
            // covers were sent: a downstream reader that observes `f` is
            // guaranteed a subsequent drain sees every row with ts <= f.
            let f = self.stages[0]
                .chain
                .as_ref()
                .expect("hosted")
                .out_frontier(self.src_frontier);
            self.fabric.boundary(0).publish_frontier(self.task_id, f);
        }
        self.rows = rows;
        res
    }

    /// One pass over the downstream stages: drain, gate, process, route,
    /// publish.  With `finishing`, stages whose inbound boundary has
    /// fully completed are flushed and marked done; returns whether every
    /// hosted stage has finished.
    fn pump(
        &mut self,
        now_micros: u64,
        out: &mut Vec<Record>,
        finishing: bool,
    ) -> Result<bool, String> {
        let mut complete = true;
        for s in 1..self.stages.len() {
            if self.stages[s].chain.is_none() || self.stages[s].finished {
                continue;
            }
            let b = s - 1;
            // Read the frontier BEFORE draining: every packet carrying
            // ts <= safe was sent before its upstream published that
            // frontier value, so a drain issued after this read observes
            // it (channel mutex + SeqCst publish ordering).
            let safe = self.fabric.boundary(b).safe_frontier();
            let mut drain_buf = std::mem::take(&mut self.drain_buf);
            drain_buf.clear();
            // Stashed packets first: they were pulled off the channel
            // even earlier (while we waited on a full outbound queue),
            // so the safe-before-drain ordering still covers them.
            let mut stash = std::mem::take(&mut self.stages[s].stash);
            drain_buf.append(&mut stash);
            self.stages[s].stash = stash;
            self.fabric
                .boundary(b)
                .drain(self.task_id, &mut drain_buf, usize::MAX);
            let mut rows = std::mem::take(&mut self.stages[s].rows);
            rows.clear();
            {
                let stats = &mut self.boundary_stats[b];
                let slot = &mut self.stages[s];
                for pkt in drain_buf.drain(..) {
                    stats.events_out += pkt.rows.len() as u64;
                    stats.exchange_wait_micros = stats
                        .exchange_wait_micros
                        .max(now_micros.saturating_sub(pkt.sent_micros));
                    if slot.gated {
                        slot.gate.absorb(&pkt.rows);
                    } else {
                        rows.extend_from(&pkt.rows);
                    }
                }
                if slot.gated {
                    slot.gate.release_into(safe, &mut rows);
                }
            }
            self.drain_buf = drain_buf;

            let has_next = s + 1 < self.stages.len();
            let chain = self.stages[s].chain.as_mut().expect("checked hosted");
            chain.note_watermark(safe);
            let res = chain.process_rows(now_micros, &mut rows, out);
            if let Err(e) = res {
                self.stages[s].rows = rows;
                return Err(e);
            }
            // The stage's output must move on (or be dropped, for the
            // final stage whose emits went to `out`) before any
            // end-of-stream flush — flushing over the stage's own output
            // would re-ingest it.
            if has_next {
                if let Err(e) = self.route_to(s, &mut rows, now_micros) {
                    self.stages[s].rows = rows;
                    return Err(e);
                }
            } else {
                rows.clear();
            }

            // Is this stage's input exhausted for good?
            let inbound_done = finishing
                && self.fabric.boundary(b).all_done()
                && self.fabric.boundary(b).is_drained(self.task_id)
                && self.stages[s].stash.is_empty()
                && self.stages[s].gate.is_empty();
            if inbound_done {
                // No final watermark push: event-time windows finalize
                // their remaining panes through finish_rows' flush (an
                // u64::MAX observation would fast-forward them to a
                // far-future empty emission).
                let chain = self.stages[s].chain.as_mut().expect("checked hosted");
                let res = chain.finish_rows(now_micros, &mut rows, out);
                if let Err(e) = res {
                    self.stages[s].rows = rows;
                    return Err(e);
                }
                if has_next {
                    if let Err(e) = self.route_to(s, &mut rows, now_micros) {
                        self.stages[s].rows = rows;
                        return Err(e);
                    }
                } else {
                    rows.clear();
                }
            }
            if has_next {
                let chain = self.stages[s].chain.as_ref().expect("checked hosted");
                let f = chain.out_frontier(safe);
                // Published after every send it covers (same ordering
                // contract as the source frontier).
                self.fabric.boundary(s).publish_frontier(self.task_id, f);
            }
            if inbound_done {
                if has_next {
                    self.fabric.boundary(s).finish_upstream(self.task_id);
                }
                self.stages[s].finished = true;
            } else {
                complete = false;
            }
            self.stages[s].rows = rows;
        }
        Ok(complete)
    }

    /// Flush stage 0 (end of the broker stream) and mark this task done
    /// on boundary 0.  Idempotent.
    pub fn finish_source(&mut self, now_micros: u64, out: &mut Vec<Record>) -> Result<(), String> {
        if self.source_finished {
            return Ok(());
        }
        self.source_finished = true;
        let mut rows = std::mem::take(&mut self.rows);
        rows.clear();
        let mut res = self
            .stages[0]
            .chain
            .as_mut()
            .expect("stage 0 is hosted on every task")
            .finish_rows(now_micros, &mut rows, out);
        if res.is_ok() {
            res = self.route_to(0, &mut rows, now_micros);
        }
        // Mark done even on a failed route: peers must not wait on a
        // task that is about to error out.
        self.fabric.boundary(0).finish_upstream(self.task_id);
        self.rows = rows;
        res
    }

    /// One finishing pass over the downstream stages; returns `true` once
    /// every hosted stage has flushed.  Callers that own all tasks
    /// single-threaded (the lockstep harness) alternate this across
    /// tasks; the engine's task threads loop it with a short sleep.
    pub fn pump_finish(&mut self, now_micros: u64, out: &mut Vec<Record>) -> Result<bool, String> {
        self.pump(now_micros, out, true)
    }

    /// Rows this task routed across all boundaries (send side).
    pub fn routed_records(&self) -> u64 {
        self.boundary_stats.iter().map(|s| s.exchange_records).sum()
    }

    /// Serialize this task's staged state for an aligned checkpoint:
    /// source frontier, each hosted stage's operator chain, and the
    /// completeness gates' pending rows.  Requires a quiesced task — no
    /// stashed packets and no rows parked in the stage working sets —
    /// which the aligned protocol guarantees at epoch boundaries (the
    /// lockstep driver additionally verifies the fabric channels are
    /// drained).
    pub fn snapshot_state(&self) -> Result<Json, String> {
        let mut stages = Vec::with_capacity(self.stages.len());
        for (s, slot) in self.stages.iter().enumerate() {
            if !slot.stash.is_empty() {
                return Err(format!(
                    "task {}: stage {s} holds {} stashed exchange packets — \
                     an aligned snapshot requires a quiesced fabric",
                    self.task_id,
                    slot.stash.len()
                ));
            }
            let mut o = Json::obj();
            o.set(
                "chain",
                match &slot.chain {
                    Some(c) => c.snapshot_ops(),
                    None => Json::Null,
                },
            );
            o.set(
                "gate",
                Json::Arr(
                    slot.gate
                        .pending
                        .iter()
                        .map(|&(ts, key, bits, count)| {
                            Json::Arr(vec![
                                Json::Int(ts as i64),
                                Json::Int(key as i64),
                                Json::Int(bits as i64),
                                Json::Int(count as i64),
                            ])
                        })
                        .collect(),
                ),
            );
            stages.push(o);
        }
        let mut j = Json::obj();
        j.set("src_frontier", Json::Int(self.src_frontier as i64));
        j.set("stages", Json::Arr(stages));
        Ok(j)
    }

    /// Restore state captured by [`StagedChain::snapshot_state`] into a
    /// freshly compiled task of the same spec and parallelism.  Frontiers
    /// are not restored here — the driver re-publishes the fabric's
    /// snapshot (monotone, so always safe) and the continued rounds keep
    /// them moving.
    pub fn restore_state(&mut self, state: &Json) -> Result<(), String> {
        let src = state
            .get("src_frontier")
            .and_then(|v| v.as_i64())
            .ok_or("checkpoint state: staged task is missing 'src_frontier'")?
            as u64;
        let stages = state
            .get("stages")
            .and_then(|v| v.as_arr())
            .ok_or("checkpoint state: staged task is missing 'stages'")?;
        if stages.len() != self.stages.len() {
            return Err(format!(
                "checkpoint holds {} stages but the pipeline has {} — \
                 the checkpoint was taken from a different pipeline spec",
                stages.len(),
                self.stages.len()
            ));
        }
        for (s, (slot, st)) in self.stages.iter_mut().zip(stages).enumerate() {
            let chain_state = st.get("chain").unwrap_or(&Json::Null);
            match (&mut slot.chain, chain_state) {
                (Some(_), Json::Null) => {
                    return Err(format!(
                        "checkpoint stage {s} was not hosted on task {} but is now — \
                         the checkpoint was taken at a different parallelism",
                        self.task_id
                    ));
                }
                (Some(c), cs) => c
                    .restore_ops(cs)
                    .map_err(|e| format!("task {} stage {s}: {e}", self.task_id))?,
                (None, Json::Null) => {}
                (None, _) => {
                    return Err(format!(
                        "checkpoint stage {s} was hosted on task {} but is not now — \
                         the checkpoint was taken at a different parallelism",
                        self.task_id
                    ));
                }
            }
            slot.gate.pending.clear();
            let gate = st
                .get("gate")
                .and_then(|v| v.as_arr())
                .ok_or("checkpoint state: staged stage is missing 'gate'")?;
            for row in gate {
                let t = row
                    .as_arr()
                    .filter(|a| a.len() == 4)
                    .ok_or("checkpoint state: gate row is not a 4-tuple")?;
                let int = |i: usize| {
                    t[i].as_i64()
                        .ok_or("checkpoint state: gate row holds a non-integer")
                };
                slot.gate
                    .pending
                    .push((int(0)? as u64, int(1)? as u32, int(2)? as u32, int(3)? as u64));
            }
        }
        self.src_frontier = src;
        Ok(())
    }
}

impl PipelineStep for StagedChain {
    fn name(&self) -> &str {
        &self.label
    }

    fn needs_parse(&self) -> bool {
        true
    }

    fn process(
        &mut self,
        now_micros: u64,
        _records: &[Record],
        batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.run_source(now_micros, batch, out)?;
        self.pump(now_micros, out, false)?;
        Ok(())
    }

    fn idle(&mut self, now_micros: u64, out: &mut Vec<Record>) -> Result<(), String> {
        // Keep the source frontier moving while the broker is quiet so
        // downstream watermarks (min-merged over upstreams) never stall
        // on an idle task, then drain whatever other tasks routed here.
        if !self.source_finished {
            let f = self.stages[0]
                .chain
                .as_ref()
                .expect("stage 0 is hosted on every task")
                .out_frontier(self.idle_source_frontier(now_micros));
            self.fabric.boundary(0).publish_frontier(self.task_id, f);
        }
        self.pump(now_micros, out, false)?;
        Ok(())
    }

    fn finish(&mut self, now_micros: u64, out: &mut Vec<Record>) -> Result<(), String> {
        self.finish_source(now_micros, out)?;
        // Escape hatch: a sibling task that died (panicked past its
        // abort hook) never marks its boundaries done; bail with an
        // error after a generous drain window instead of hanging the
        // engine join forever.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        loop {
            if self.pump_finish(now_micros, out)? {
                return Ok(());
            }
            if std::time::Instant::now() >= deadline {
                return Err(format!(
                    "task {}: exchange finish timed out — an upstream task \
                     likely died without flushing its stages",
                    self.task_id
                ));
            }
            // Other task threads are still flushing their stages into our
            // boundaries; yield briefly and re-drain.
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }

    /// Abandon the staged chain (task error path): mark this task done on
    /// every boundary it feeds so sibling finish drains terminate.
    fn abort(&mut self) {
        if !self.source_finished {
            self.source_finished = true;
            self.fabric.boundary(0).finish_upstream(self.task_id);
        }
        for s in 1..self.stages.len() {
            if self.stages[s].chain.is_some() && !self.stages[s].finished {
                self.stages[s].finished = true;
                if s < self.stages.len() - 1 {
                    self.fabric.boundary(s).finish_upstream(self.task_id);
                }
            }
        }
    }

    fn stats(&self) -> StepStats {
        let mut s = StepStats::default();
        for (_, o) in self.operator_stats() {
            s.merge(&o);
        }
        // The merge summed per-op intake/output; step-level semantics are
        // the source intake and the records actually egested.
        s.events_in = self.stages[0]
            .chain
            .as_ref()
            .and_then(|c| c.operator_stats().first().map(|(_, o)| o.events_in))
            .unwrap_or(0);
        s.events_out = self
            .stages
            .iter()
            .filter_map(|slot| slot.chain.as_ref().map(|c| c.stats().events_out))
            .sum();
        s
    }

    fn snapshot(&self) -> Result<Json, String> {
        self.snapshot_state()
    }

    fn restore(&mut self, state: &Json) -> Result<(), String> {
        self.restore_state(state)
    }

    /// Full staged op list — identical names on every task (stats are
    /// merged positionally across tasks), with one `exchange` entry per
    /// boundary between its stages.
    fn operator_stats(&self) -> Vec<(String, StepStats)> {
        let mut ops = Vec::new();
        for (s, slot) in self.stages.iter().enumerate() {
            if s > 0 {
                ops.push(("exchange".to_string(), self.boundary_stats[s - 1]));
            }
            match &slot.chain {
                Some(c) => ops.extend(c.operator_stats()),
                None => ops.extend(
                    slot.op_names
                        .iter()
                        .map(|n| (n.clone(), StepStats::default())),
                ),
            }
        }
        ops
    }
}

/// Deterministic single-threaded driver over a full staged pipeline: all
/// task instances advance in lockstep rounds, so two runs over the same
/// input — at *any* parallelism — drain the exchange in the same order.
/// The equivalence suite and the `hotpath_micro` shuffle case run on it.
pub struct LockstepExchange {
    tasks: Vec<StagedChain>,
    fabric: Arc<ExchangeFabric>,
}

impl LockstepExchange {
    /// Build the staged pipeline for `cfg`'s effective spec.  Returns
    /// `None` when the spec does not stage (no keyed boundary, or
    /// `engine.exchange: none`).
    pub fn compile(cfg: &BenchConfig) -> Result<Option<LockstepExchange>, String> {
        if cfg.engine.exchange == ExchangeMode::None {
            return Ok(None);
        }
        let spec = cfg.engine.effective_spec();
        let stages = spec.split_stages(cfg.engine.parallelism);
        if stages.len() < 2 {
            return Ok(None);
        }
        let fabric = Arc::new(ExchangeFabric::new(&stages, StagedChain::channel_capacity()));
        let label = cfg.engine.pipeline_label();
        let tasks = (0..cfg.engine.parallelism)
            .map(|t| {
                StagedChain::compile(cfg, &stages, label.clone(), t, fabric.clone(), None, None, 0)
            })
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Some(LockstepExchange { tasks, fabric }))
    }

    pub fn parallelism(&self) -> u32 {
        self.tasks.len() as u32
    }

    /// Total rows routed across every boundary so far.
    pub fn routed_records(&self) -> u64 {
        self.fabric.total_records()
    }

    /// One lockstep round at `now`: task `t` ingests `batches[t]` (tasks
    /// beyond the slice idle), then drains its inbound boundaries.
    pub fn process_round(
        &mut self,
        now_micros: u64,
        batches: &[EventBatch],
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        for (t, task) in self.tasks.iter_mut().enumerate() {
            match batches.get(t) {
                Some(b) if !b.is_empty() => task.process(now_micros, &[], b, out)?,
                _ => task.idle(now_micros, out)?,
            }
        }
        Ok(())
    }

    /// An input-less round: every task publishes its frontier and drains.
    pub fn idle_round(&mut self, now_micros: u64, out: &mut Vec<Record>) -> Result<(), String> {
        self.process_round(now_micros, &[], out)
    }

    /// Flush the whole staged pipeline deterministically: every task
    /// closes its source, then finishing passes alternate across tasks
    /// until each stage has drained (at most one pass per stage per task
    /// round, bounded by the stage count).
    pub fn finish(&mut self, now_micros: u64, out: &mut Vec<Record>) -> Result<(), String> {
        for task in &mut self.tasks {
            task.finish_source(now_micros, out)?;
        }
        // Each round completes at least one more stage tier across all
        // tasks, so stages+2 rounds always suffice; the cap is a
        // belt-and-braces guard against a wiring bug looping forever.
        let mut rounds = 0usize;
        loop {
            let mut all = true;
            for task in &mut self.tasks {
                if !task.pump_finish(now_micros, out)? {
                    all = false;
                }
            }
            if all {
                return Ok(());
            }
            rounds += 1;
            if rounds > self.tasks.len() * 16 + 64 {
                return Err("lockstep finish failed to converge — exchange wiring bug".into());
            }
        }
    }

    /// Aligned snapshot of the whole staged pipeline.  Valid only at a
    /// quiesce point — every boundary channel drained, no stashed packets
    /// — which lockstep rounds reach after each `process_round` +
    /// `idle_round` pair; refuses (readable error) otherwise.  Captures
    /// every task's operator/gate state plus the fabric's per-upstream
    /// frontiers, so a restored pipeline resumes exactly where the
    /// snapshot was taken.
    pub fn snapshot(&self) -> Result<Json, String> {
        for b in 0..self.fabric.boundary_count() {
            let bd = self.fabric.boundary(b);
            for d in 0..bd.downstreams() {
                if !bd.is_drained(d) {
                    return Err(format!(
                        "boundary {b} still holds packets for instance {d} — \
                         an aligned snapshot requires a quiesced fabric \
                         (run an idle round first)"
                    ));
                }
            }
        }
        let tasks = self
            .tasks
            .iter()
            .map(|t| t.snapshot_state())
            .collect::<Result<Vec<_>, _>>()?;
        let frontiers = (0..self.fabric.boundary_count())
            .map(|b| {
                Json::Arr(
                    self.fabric
                        .boundary(b)
                        .frontiers()
                        .into_iter()
                        .map(|f| Json::Int(f as i64))
                        .collect(),
                )
            })
            .collect();
        let mut j = Json::obj();
        j.set("tasks", Json::Arr(tasks));
        j.set("frontiers", Json::Arr(frontiers));
        Ok(j)
    }

    /// Restore a [`LockstepExchange::snapshot`] into a freshly compiled
    /// pipeline of the same spec and parallelism: per-task state, then the
    /// fabric frontiers (re-published, which is monotone and safe).
    pub fn restore(&mut self, state: &Json) -> Result<(), String> {
        let tasks = state
            .get("tasks")
            .and_then(|v| v.as_arr())
            .ok_or("checkpoint state: staged snapshot is missing 'tasks'")?;
        if tasks.len() != self.tasks.len() {
            return Err(format!(
                "checkpoint holds {} tasks but the pipeline runs {} — \
                 restore requires the checkpoint's parallelism",
                tasks.len(),
                self.tasks.len()
            ));
        }
        for (task, st) in self.tasks.iter_mut().zip(tasks) {
            task.restore_state(st)?;
        }
        let frontiers = state
            .get("frontiers")
            .and_then(|v| v.as_arr())
            .ok_or("checkpoint state: staged snapshot is missing 'frontiers'")?;
        for (b, per_up) in frontiers.iter().enumerate().take(self.fabric.boundary_count()) {
            let arr = per_up
                .as_arr()
                .ok_or("checkpoint state: boundary frontiers are not an array")?;
            for (u, f) in arr.iter().enumerate() {
                let f = f
                    .as_i64()
                    .ok_or("checkpoint state: frontier is not an integer")?;
                self.fabric.boundary(b).publish_frontier(u as u32, f as u64);
            }
        }
        Ok(())
    }

    /// Per-operator stats merged positionally across the task instances
    /// (the same shape the engine reports).
    pub fn operator_stats(&self) -> Vec<(String, StepStats)> {
        let mut merged: Vec<(String, StepStats)> = Vec::new();
        for task in &self.tasks {
            for (i, (name, stats)) in task.operator_stats().iter().enumerate() {
                match merged.get_mut(i) {
                    Some((n, m)) if n == name => m.merge(stats),
                    _ => merged.push((name.clone(), *stats)),
                }
            }
        }
        merged
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OpSpec;
    use crate::engine::window::AggKind;

    fn keyed_cfg(parallelism: u32) -> BenchConfig {
        let mut cfg = BenchConfig::default();
        cfg.engine.use_hlo = false;
        cfg.engine.parallelism = parallelism;
        cfg.workload.sensors = 64;
        cfg.engine.pipeline_spec = Some(PipelineSpec {
            ops: vec![
                OpSpec::KeyBy {
                    modulo: 16,
                    parallelism: 0,
                },
                OpSpec::window(AggKind::Sum, 1_000_000, 500_000),
                OpSpec::EmitAggregates,
            ],
        });
        cfg
    }

    fn batch(keys: &[u32], vals: &[f32], ts: u64) -> EventBatch {
        EventBatch {
            ids: keys.to_vec(),
            temps: vals.to_vec(),
            gen_ts: vec![ts; keys.len()],
            append_ts: vec![ts; keys.len()],
            payload_bytes: keys.len() as u64 * 27,
        }
    }

    #[test]
    fn flat_specs_do_not_stage() {
        let mut cfg = BenchConfig::default();
        cfg.engine.use_hlo = false;
        assert!(LockstepExchange::compile(&cfg).unwrap().is_none());
        let mut cfg = keyed_cfg(2);
        cfg.engine.exchange = ExchangeMode::None;
        assert!(LockstepExchange::compile(&cfg).unwrap().is_none());
    }

    #[test]
    fn keyed_state_sees_whole_groups_across_tasks() {
        // Keys 3 and 19 both map to derived key 3 (mod 16); feed them to
        // *different* tasks and the exchange must still aggregate them in
        // one window state.
        let mut lx = LockstepExchange::compile(&keyed_cfg(2)).unwrap().unwrap();
        let mut out = Vec::new();
        let t0 = 100_000u64;
        lx.process_round(
            t0,
            &[batch(&[3], &[10.0], t0), batch(&[19], &[32.0], t0)],
            &mut out,
        )
        .unwrap();
        lx.finish(600_000, &mut out).unwrap();
        assert!(lx.routed_records() >= 2, "rows must cross the exchange");
        let payloads: Vec<String> = out
            .iter()
            .map(|r| String::from_utf8(r.payload().to_vec()).unwrap())
            .collect();
        // One merged aggregate for derived key 3: 10 + 32 = 42.
        let merged: Vec<&String> = payloads
            .iter()
            .filter(|p| p.contains("\"id\":3,"))
            .collect();
        assert_eq!(merged.len(), 1, "one window emission for key 3: {payloads:?}");
        assert!(
            merged[0].contains("\"sum\":42.000"),
            "split keyed state: {merged:?}"
        );
        assert!(merged[0].contains("\"n\":2"), "{merged:?}");
    }

    #[test]
    fn snapshot_requires_a_quiesced_fabric() {
        let mut lx = LockstepExchange::compile(&keyed_cfg(2)).unwrap().unwrap();
        let mut out = Vec::new();
        // Key 0 hashes to instance 0, and task 0 pumps *before* task 1
        // sends in a round — so task 1's packet is still queued when the
        // round ends.
        lx.process_round(
            100_000,
            &[EventBatch::default(), batch(&[0], &[1.0], 100_000)],
            &mut out,
        )
        .unwrap();
        let err = lx.snapshot().unwrap_err();
        assert!(err.contains("quiesced"), "{err}");
        // One idle round drains the queued packet; the snapshot succeeds.
        lx.idle_round(100_000, &mut out).unwrap();
        assert!(lx.snapshot().is_ok());
    }

    #[test]
    fn lockstep_snapshot_restore_resumes_byte_identically() {
        let rounds: Vec<(u64, Vec<EventBatch>)> = (0..8)
            .map(|r| {
                let ts = 100_000 + r * 200_000;
                (
                    ts + 10_000,
                    vec![
                        batch(&[3, 19, 7], &[1.0 + r as f32, 2.0, 3.5], ts),
                        batch(&[35, 4, 11], &[4.0, 5.0 + r as f32, 6.5], ts + 50_000),
                    ],
                )
            })
            .collect();
        let finish_at = 3_000_000u64;
        let canon = |out: &[Record]| {
            let mut v: Vec<String> = out
                .iter()
                .map(|r| String::from_utf8(r.payload().to_vec()).unwrap())
                .collect();
            v.sort();
            v
        };

        // Reference: the unkilled run.
        let mut full = LockstepExchange::compile(&keyed_cfg(2)).unwrap().unwrap();
        let mut full_out = Vec::new();
        for (now, batches) in &rounds {
            full.process_round(*now, batches, &mut full_out).unwrap();
        }
        full.finish(finish_at, &mut full_out).unwrap();

        // Killed run: snapshot after round 3 (mid-window), throw the
        // pipeline away, restore into a fresh compile, replay the rest.
        let mut first = LockstepExchange::compile(&keyed_cfg(2)).unwrap().unwrap();
        let mut killed_out = Vec::new();
        for (now, batches) in &rounds[..4] {
            first.process_round(*now, batches, &mut killed_out).unwrap();
        }
        let quiesce_now = rounds[3].0;
        first.idle_round(quiesce_now, &mut killed_out).unwrap();
        let snap = first.snapshot().unwrap();
        drop(first); // the crash

        let mut resumed = LockstepExchange::compile(&keyed_cfg(2)).unwrap().unwrap();
        resumed.restore(&snap).unwrap();
        for (now, batches) in &rounds[4..] {
            resumed
                .process_round(*now, batches, &mut killed_out)
                .unwrap();
        }
        resumed.finish(finish_at, &mut killed_out).unwrap();

        assert!(!full_out.is_empty());
        assert_eq!(
            canon(&full_out),
            canon(&killed_out),
            "kill+restore must not change any emitted aggregate"
        );
    }

    #[test]
    fn restore_rejects_parallelism_mismatch_readably() {
        let mut lx = LockstepExchange::compile(&keyed_cfg(2)).unwrap().unwrap();
        let mut out = Vec::new();
        lx.idle_round(50_000, &mut out).unwrap();
        let snap = lx.snapshot().unwrap();
        let mut wider = LockstepExchange::compile(&keyed_cfg(4)).unwrap().unwrap();
        let err = wider.restore(&snap).unwrap_err();
        assert!(err.contains("parallelism"), "{err}");
    }

    #[test]
    fn exchange_stats_flow_into_operator_stats() {
        let mut lx = LockstepExchange::compile(&keyed_cfg(2)).unwrap().unwrap();
        let mut out = Vec::new();
        lx.process_round(
            50_000,
            &[batch(&[1, 2], &[1.0, 2.0], 50_000), batch(&[3], &[3.0], 50_000)],
            &mut out,
        )
        .unwrap();
        lx.finish(700_000, &mut out).unwrap();
        let ops = lx.operator_stats();
        let names: Vec<&str> = ops.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec!["keyby", "exchange", "window", "emit_aggregates"],
            "exchange entry sits at the stage boundary"
        );
        let (_, x) = &ops[1];
        assert_eq!(x.exchange_records, 3, "all rows cross the boundary");
        assert_eq!(x.events_in, 3);
        assert_eq!(x.events_out, 3, "sent == drained after finish");
        assert_eq!(x.exchange_bytes, 3 * ROW_WIRE_BYTES);
    }
}
