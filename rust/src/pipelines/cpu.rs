//! CPU-intensive pipeline (paper Sec. 3.3, red path).
//!
//! Parses incoming sensor events into tuples, converts °C → °F, and checks
//! the converted value against an alert threshold; the transformed stream
//! is forwarded to the egestion broker.  The per-batch math is the
//! `cpu_pipeline_step` HLO artifact (L1 Pallas `sensor_transform` kernel)
//! executed via PJRT, with a native Rust path as the ablation baseline.
//!
//! Since the operator-chain redesign the production path is the canonical
//! `[cpu_transform, emit_events]` chain; this struct is the reference
//! implementation the equivalence suite compares against.

use super::{Compute, PipelineStep, StepStats};
use crate::broker::Record;
use crate::engine::EventBatch;
use crate::runtime::Input;
use crate::wgen::{EventFormat, SensorEvent};

pub struct CpuIntensive {
    compute: Compute,
    threshold_f: f32,
    event_bytes: usize,
    stats: StepStats,
    // Reused marshalling buffers (no allocation on the batch path).
    temps_pad: Vec<f32>,
    wire: Vec<u8>,
}

impl CpuIntensive {
    pub fn new(compute: Compute, threshold_f: f32, event_bytes: usize) -> Self {
        Self {
            compute,
            threshold_f,
            event_bytes,
            stats: StepStats::default(),
            temps_pad: Vec::new(),
            wire: Vec::new(),
        }
    }

    /// Compute °F + alert mask for `temps`, via HLO or natively.
    /// Batches larger than the biggest artifact variant are chunked.
    fn transform(&mut self, temps: &[f32]) -> Result<(Vec<f32>, Vec<f32>), String> {
        match &self.compute {
            Compute::Hlo(rt) => {
                let mut f = Vec::with_capacity(temps.len());
                let mut a = Vec::with_capacity(temps.len());
                let thresh = [self.threshold_f];
                let mut off = 0;
                while off < temps.len() {
                    let remaining = temps.len() - off;
                    let artifact = rt.select("cpu_pipeline_step", remaining)?;
                    let b = artifact.batch;
                    let name = artifact.name.clone();
                    let take = b.min(remaining);
                    self.temps_pad.clear();
                    self.temps_pad.extend_from_slice(&temps[off..off + take]);
                    self.temps_pad.resize(b, 0.0);
                    let out = rt.execute_f32(
                        &name,
                        &[Input::F32(&self.temps_pad), Input::F32(&thresh)],
                    )?;
                    self.stats.hlo_calls += 1;
                    let mut it = out.into_iter();
                    let fo = it.next().ok_or("missing fahr output")?;
                    let ao = it.next().ok_or("missing alerts output")?;
                    f.extend_from_slice(&fo[..take]);
                    a.extend_from_slice(&ao[..take]);
                    off += take;
                }
                Ok((f, a))
            }
            Compute::Native => {
                let f: Vec<f32> = temps.iter().map(|t| t * 9.0 / 5.0 + 32.0).collect();
                let a: Vec<f32> = f
                    .iter()
                    .map(|&x| if x > self.threshold_f { 1.0 } else { 0.0 })
                    .collect();
                Ok((f, a))
            }
        }
    }
}

impl PipelineStep for CpuIntensive {
    fn name(&self) -> &str {
        "cpu"
    }

    fn process(
        &mut self,
        _now_micros: u64,
        _records: &[Record],
        batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        if batch.is_empty() {
            return Ok(());
        }
        self.stats.events_in += batch.len() as u64;
        let (fahr, alerts) = self.transform(&batch.temps)?;
        for i in 0..batch.len() {
            if alerts[i] > 0.5 {
                self.stats.alerts += 1;
            }
            let ev = SensorEvent {
                ts_micros: batch.gen_ts[i],
                sensor_id: batch.ids[i],
                temp_c: fahr[i], // transformed value on the wire
            };
            let fmt = if self.event_bytes < 40 {
                EventFormat::Csv
            } else {
                EventFormat::Json
            };
            ev.serialize_into(fmt, self.event_bytes, &mut self.wire);
            out.push(Record::new(batch.ids[i], self.wire.as_slice(), batch.gen_ts[i]));
        }
        self.stats.events_out += batch.len() as u64;
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RuntimeFactory;

    fn batch(temps: &[f32]) -> EventBatch {
        EventBatch {
            ids: (0..temps.len() as u32).collect(),
            temps: temps.to_vec(),
            gen_ts: vec![100; temps.len()],
            append_ts: vec![105; temps.len()],
            payload_bytes: temps.len() as u64 * 27,
        }
    }

    #[test]
    fn native_transform_converts_and_alerts() {
        let mut p = CpuIntensive::new(Compute::Native, 80.0, 27);
        let b = batch(&[0.0, 100.0, -40.0]);
        let mut out = Vec::new();
        p.process(0, &[], &b, &mut out).unwrap();
        assert_eq!(out.len(), 3);
        let e0 = SensorEvent::parse(out[0].payload()).unwrap();
        assert!((e0.temp_c - 32.0).abs() < 0.01);
        let e1 = SensorEvent::parse(out[1].payload()).unwrap();
        assert!((e1.temp_c - 212.0).abs() < 0.01);
        let s = p.stats();
        assert_eq!(s.alerts, 1); // only 212°F > 80°F
        assert_eq!(s.events_out, 3);
    }

    #[test]
    fn hlo_matches_native() {
        let f = RuntimeFactory::default_dir();
        if !f.available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let temps: Vec<f32> = (0..300).map(|i| i as f32 / 3.0 - 40.0).collect();
        let mut native = CpuIntensive::new(Compute::Native, 80.0, 27);
        let mut hlo = CpuIntensive::new(Compute::Hlo(f.create().unwrap()), 80.0, 27);
        let b = batch(&temps);
        let (mut out_n, mut out_h) = (Vec::new(), Vec::new());
        native.process(0, &[], &b, &mut out_n).unwrap();
        hlo.process(0, &[], &b, &mut out_h).unwrap();
        assert_eq!(out_n.len(), out_h.len());
        for (n, h) in out_n.iter().zip(&out_h) {
            let en = SensorEvent::parse(n.payload()).unwrap();
            let eh = SensorEvent::parse(h.payload()).unwrap();
            assert!((en.temp_c - eh.temp_c).abs() < 0.02, "{} vs {}", en.temp_c, eh.temp_c);
        }
        assert_eq!(native.stats().alerts, hlo.stats().alerts);
        assert_eq!(hlo.stats().hlo_calls, 1);
    }

    #[test]
    fn batch_larger_than_any_artifact_is_an_error_free_path() {
        // select() falls back to the largest artifact; the transform pads
        // only up to that size, so oversized batches must be chunked by the
        // task layer. Here we verify select's fallback contract via the
        // native path (no artifacts needed).
        let mut p = CpuIntensive::new(Compute::Native, 50.0, 27);
        let temps = vec![10.0f32; 5000];
        let b = batch(&temps);
        let mut out = Vec::new();
        p.process(0, &[], &b, &mut out).unwrap();
        assert_eq!(out.len(), 5000);
    }

    #[test]
    fn empty_batch_is_noop() {
        let mut p = CpuIntensive::new(Compute::Native, 80.0, 27);
        let mut out = Vec::new();
        p.process(0, &[], &EventBatch::default(), &mut out).unwrap();
        assert!(out.is_empty());
        assert_eq!(p.stats().events_in, 0);
    }
}
