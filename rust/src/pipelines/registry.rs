//! Named registry for user-defined operators.
//!
//! The paper's extensibility promise (Sec. 3.3: "users can also define
//! custom processing logic … with minimal modifications") maps onto the
//! operator-chain API here: implement [`Operator`], register a builder
//! under a name, and reference that name from the `pipeline: {ops: [...]}`
//! config spec — the chain compiler resolves it per engine-task thread.
//! See `examples/custom_pipeline.rs` for the worked example.

use std::collections::BTreeMap;

use super::operator::Operator;
use crate::config::BenchConfig;
use crate::util::json::Json;

/// What a builder gets to work with: the resolved run configuration and
/// the task's start time (window alignment).
pub struct OpContext<'a> {
    pub config: &'a BenchConfig,
    pub start_micros: u64,
}

/// Builds one thread-confined operator instance from its spec parameters.
/// Called once per engine-task thread.
pub type OperatorBuilder =
    Box<dyn Fn(&Json, &OpContext<'_>) -> Result<Box<dyn Operator>, String> + Send + Sync>;

/// Name → builder map shared by every engine task (`Send + Sync`; the
/// operators it builds are not, they stay on their task thread).
#[derive(Default)]
pub struct OperatorRegistry {
    builders: BTreeMap<String, OperatorBuilder>,
}

impl OperatorRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `builder` under `name`; re-registering a name replaces the
    /// previous builder (last one wins).
    pub fn register(&mut self, name: impl Into<String>, builder: OperatorBuilder) -> &mut Self {
        self.builders.insert(name.into(), builder);
        self
    }

    pub fn contains(&self, name: &str) -> bool {
        self.builders.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.builders.keys().map(|s| s.as_str()).collect()
    }

    /// Build the operator registered as `name`, or a readable error
    /// listing what is registered.
    pub fn build(
        &self,
        name: &str,
        params: &Json,
        ctx: &OpContext<'_>,
    ) -> Result<Box<dyn Operator>, String> {
        match self.builders.get(name) {
            Some(b) => b(params, ctx)
                .map_err(|e| format!("building custom operator '{name}': {e}")),
            None => Err(format!(
                "unknown operator '{name}' — registered custom operators: [{}]",
                self.names().join(", ")
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::Record;
    use crate::pipelines::operator::RowBatch;
    use crate::pipelines::StepStats;

    struct Doubler {
        stats: StepStats,
    }

    impl Operator for Doubler {
        fn name(&self) -> &str {
            "doubler"
        }

        fn apply(
            &mut self,
            _now: u64,
            rows: &mut RowBatch,
            _out: &mut Vec<Record>,
        ) -> Result<(), String> {
            self.stats.events_in += rows.len() as u64;
            for v in &mut rows.vals {
                *v *= 2.0;
            }
            self.stats.events_out += rows.len() as u64;
            Ok(())
        }

        fn stats(&self) -> StepStats {
            self.stats
        }
    }

    #[test]
    fn registered_builder_resolves_and_builds() {
        let mut reg = OperatorRegistry::new();
        reg.register(
            "doubler",
            Box::new(|_params, _ctx| {
                Ok(Box::new(Doubler {
                    stats: StepStats::default(),
                }) as Box<dyn Operator>)
            }),
        );
        assert!(reg.contains("doubler"));
        let cfg = BenchConfig::default();
        let ctx = OpContext {
            config: &cfg,
            start_micros: 0,
        };
        let mut op = reg.build("doubler", &Json::obj(), &ctx).unwrap();
        let mut rows = RowBatch::default();
        rows.push(1, 3.0, 0, 1);
        let mut out = Vec::new();
        op.apply(0, &mut rows, &mut out).unwrap();
        assert_eq!(rows.vals, vec![6.0]);
    }

    #[test]
    fn unknown_name_lists_registered_ops() {
        let mut reg = OperatorRegistry::new();
        reg.register("a_op", Box::new(|_, _| Err("unused".into())));
        let cfg = BenchConfig::default();
        let ctx = OpContext {
            config: &cfg,
            start_micros: 0,
        };
        let err = reg.build("nope", &Json::obj(), &ctx).unwrap_err();
        assert!(err.contains("nope") && err.contains("a_op"), "{err}");
    }

    #[test]
    fn builder_params_reach_the_builder() {
        let mut reg = OperatorRegistry::new();
        reg.register(
            "strict",
            Box::new(|params, _ctx| {
                let t = params
                    .get("threshold")
                    .and_then(|v| v.as_f64())
                    .ok_or("needs `threshold:`")?;
                assert_eq!(t, 4.5);
                Ok(Box::new(Doubler {
                    stats: StepStats::default(),
                }) as Box<dyn Operator>)
            }),
        );
        let cfg = BenchConfig::default();
        let ctx = OpContext {
            config: &cfg,
            start_micros: 0,
        };
        let mut params = Json::obj();
        params.set("threshold", Json::Num(4.5));
        assert!(reg.build("strict", &params, &ctx).is_ok());
        let err = reg.build("strict", &Json::obj(), &ctx).unwrap_err();
        assert!(err.contains("threshold"), "{err}");
    }
}
