//! Fused pipeline: CPU transform + keyed window in one HLO dispatch.
//!
//! An extension beyond the paper's three pipelines (DESIGN.md lists it as
//! an ablation): the °C→°F transform feeds the sliding window directly, so
//! a single `fused_pipeline_step` artifact does the work of both pipelines
//! per batch — XLA fuses the elementwise stage into the scatter's operand.
//! The ablation bench compares one fused dispatch against two separate
//! ones (`cargo bench --bench hotpath_micro`).
//!
//! Since the operator-chain redesign the production path is the canonical
//! `[cpu_transform, emit_events, window(mean), emit_aggregates]` chain,
//! which trades the single fused HLO dispatch for composability (two
//! dispatches on the HLO path; the native paths are byte-identical).  This
//! struct keeps the genuinely fused single-dispatch kernel for the
//! ablation and is the reference implementation the equivalence suite
//! compares against.

use super::{Compute, PipelineStep, StepStats, HLO_KEYS};
use crate::broker::Record;
use crate::engine::{EventBatch, SlidingWindow, WindowEmit};
use crate::runtime::Input;
use crate::wgen::{EventFormat, SensorEvent};

pub struct Fused {
    compute: Compute,
    threshold_f: f32,
    event_bytes: usize,
    window: SlidingWindow,
    keys: usize,
    stats: StepStats,
    ids_pad: Vec<i32>,
    temps_pad: Vec<f32>,
    wire: Vec<u8>,
}

impl Fused {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        compute: Compute,
        threshold_f: f32,
        event_bytes: usize,
        sensors: usize,
        window_micros: u64,
        slide_micros: u64,
        start_micros: u64,
    ) -> Self {
        let keys = match &compute {
            Compute::Hlo(_) => sensors.min(HLO_KEYS),
            Compute::Native => sensors,
        };
        Self {
            compute,
            threshold_f,
            event_bytes,
            window: SlidingWindow::new(keys, window_micros, slide_micros, start_micros),
            keys,
            stats: StepStats::default(),
            ids_pad: Vec::new(),
            temps_pad: Vec::new(),
            wire: Vec::new(),
        }
    }

    fn emit_windows(&mut self, emits: Vec<WindowEmit>, out: &mut Vec<Record>) {
        for e in emits {
            self.stats.window_emits += 1;
            for &(key, mean, count) in &e.aggregates {
                let payload = format!(
                    "{{\"win\":{},\"id\":{},\"avg\":{:.3},\"n\":{}}}",
                    e.end_micros, key, mean, count
                );
                out.push(Record::new(key, payload.into_bytes(), e.end_micros));
                self.stats.events_out += 1;
            }
        }
    }

    fn emit_transformed(&mut self, batch: &EventBatch, fahr: &[f32], alerts: &[f32], out: &mut Vec<Record>) {
        let fmt = if self.event_bytes < 40 {
            EventFormat::Csv
        } else {
            EventFormat::Json
        };
        for i in 0..batch.len() {
            if alerts[i] > 0.5 {
                self.stats.alerts += 1;
            }
            let ev = SensorEvent {
                ts_micros: batch.gen_ts[i],
                sensor_id: batch.ids[i],
                temp_c: fahr[i],
            };
            ev.serialize_into(fmt, self.event_bytes, &mut self.wire);
            out.push(Record::new(batch.ids[i], self.wire.as_slice(), batch.gen_ts[i]));
            self.stats.events_out += 1;
        }
    }
}

impl PipelineStep for Fused {
    fn name(&self) -> &str {
        "fused"
    }

    fn process(
        &mut self,
        now_micros: u64,
        _records: &[Record],
        batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        if !batch.is_empty() {
            self.stats.events_in += batch.len() as u64;
            match &self.compute {
                Compute::Hlo(rt) => {
                    let mut fahr_all = Vec::with_capacity(batch.len());
                    let mut alerts_all = Vec::with_capacity(batch.len());
                    let thresh = [self.threshold_f];
                    let mut off = 0;
                    while off < batch.len() {
                        let remaining = batch.len() - off;
                        let artifact = rt.select("fused_pipeline_step", remaining)?;
                        let (b, k) = (artifact.batch, artifact.keys);
                        let name = artifact.name.clone();
                        let take = b.min(remaining);
                        self.ids_pad.clear();
                        self.temps_pad.clear();
                        for i in off..off + take {
                            let id = batch.ids[i] as usize;
                            self.ids_pad
                                .push(if id < self.keys { id as i32 } else { k as i32 });
                            self.temps_pad.push(batch.temps[i]);
                        }
                        self.ids_pad.resize(b, k as i32);
                        self.temps_pad.resize(b, 0.0);
                        let pane = self.window.current_pane();
                        let mut sum_state = pane.sum.clone();
                        let mut cnt_state = pane.cnt.clone();
                        sum_state.resize(k, 0.0);
                        cnt_state.resize(k, 0.0);
                        let outs = rt.execute_f32(
                            &name,
                            &[
                                Input::I32(&self.ids_pad),
                                Input::F32(&self.temps_pad),
                                Input::F32(&thresh),
                                Input::F32(&sum_state),
                                Input::F32(&cnt_state),
                            ],
                        )?;
                        self.stats.hlo_calls += 1;
                        let mut it = outs.into_iter();
                        let f = it.next().ok_or("missing fahr")?;
                        let a = it.next().ok_or("missing alerts")?;
                        let mut s = it.next().ok_or("missing sum")?;
                        let mut c = it.next().ok_or("missing cnt")?;
                        fahr_all.extend_from_slice(&f[..take]);
                        alerts_all.extend_from_slice(&a[..take]);
                        s.truncate(self.keys);
                        c.truncate(self.keys);
                        self.window.store_state(s, c);
                        off += take;
                    }
                    let fahr = std::mem::take(&mut fahr_all);
                    let alerts = std::mem::take(&mut alerts_all);
                    self.emit_transformed(batch, &fahr, &alerts, out);
                }
                Compute::Native => {
                    let fahr: Vec<f32> =
                        batch.temps.iter().map(|t| t * 9.0 / 5.0 + 32.0).collect();
                    let alerts: Vec<f32> = fahr
                        .iter()
                        .map(|&x| if x > self.threshold_f { 1.0 } else { 0.0 })
                        .collect();
                    self.window.accumulate_native(&batch.ids, &fahr);
                    self.emit_transformed(batch, &fahr, &alerts, out);
                }
            }
        }
        let emits = self.window.advance(now_micros);
        self.emit_windows(emits, out);
        Ok(())
    }

    fn finish(&mut self, now_micros: u64, out: &mut Vec<Record>) -> Result<(), String> {
        let mut emits = self.window.advance(now_micros);
        emits.extend(self.window.flush());
        self.emit_windows(emits, out);
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RuntimeFactory;
    use crate::util::json;

    fn batch(ids: &[u32], temps: &[f32], ts: u64) -> EventBatch {
        EventBatch {
            ids: ids.to_vec(),
            temps: temps.to_vec(),
            gen_ts: vec![ts; ids.len()],
            append_ts: vec![ts; ids.len()],
            payload_bytes: ids.len() as u64 * 27,
        }
    }

    #[test]
    fn native_fused_emits_transformed_plus_windows() {
        let mut p = Fused::new(Compute::Native, 80.0, 27, 8, 2_000_000, 1_000_000, 0);
        let mut out = Vec::new();
        p.process(0, &[], &batch(&[1, 2], &[0.0, 100.0], 0), &mut out)
            .unwrap();
        assert_eq!(out.len(), 2, "transformed events forwarded immediately");
        p.process(1_000_000, &[], &EventBatch::default(), &mut out)
            .unwrap();
        assert_eq!(out.len(), 4, "window aggregates for both keys");
        // Window aggregates fahrenheit (key 1: 32°F, key 2: 212°F).
        let agg = json::parse(std::str::from_utf8(out[2].payload()).unwrap()).unwrap();
        assert!((agg.get("avg").unwrap().as_f64().unwrap() - 32.0).abs() < 0.01);
        assert_eq!(p.stats().alerts, 1);
    }

    #[test]
    fn hlo_fused_matches_native() {
        let f = RuntimeFactory::default_dir();
        if !f.available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let ids: Vec<u32> = (0..400).map(|i| i % 32).collect();
        let temps: Vec<f32> = (0..400).map(|i| i as f32 / 7.0 - 20.0).collect();
        let mut native = Fused::new(Compute::Native, 80.0, 27, 32, 2_000_000, 1_000_000, 0);
        let mut hlo = Fused::new(
            Compute::Hlo(f.create().unwrap()),
            80.0,
            27,
            32,
            2_000_000,
            1_000_000,
            0,
        );
        let (mut on, mut oh) = (Vec::new(), Vec::new());
        native.process(0, &[], &batch(&ids, &temps, 0), &mut on).unwrap();
        hlo.process(0, &[], &batch(&ids, &temps, 0), &mut oh).unwrap();
        native.finish(1_000_000, &mut on).unwrap();
        hlo.finish(1_000_000, &mut oh).unwrap();
        assert_eq!(on.len(), oh.len());
        assert_eq!(native.stats().alerts, hlo.stats().alerts);
        // Compare the window aggregates (tail records).
        let tail = 32;
        for (a, b) in on[on.len() - tail..].iter().zip(&oh[oh.len() - tail..]) {
            let ja = json::parse(std::str::from_utf8(a.payload()).unwrap()).unwrap();
            let jb = json::parse(std::str::from_utf8(b.payload()).unwrap()).unwrap();
            let va = ja.get("avg").unwrap().as_f64().unwrap();
            let vb = jb.get("avg").unwrap().as_f64().unwrap();
            assert!((va - vb).abs() < 0.02, "{va} vs {vb}");
        }
    }
}
