//! Composable operator-chain pipeline API.
//!
//! The paper promises "complete customization options" for processing
//! logic (Sec. 3.3); the operator chain is how this suite delivers them.
//! A pipeline is a sequence of [`Operator`]s fused into one [`Chain`] per
//! engine-task thread.  Between operators flows a [`RowBatch`] — a
//! structure-of-arrays working set of `(key, value, timestamp, count)`
//! rows derived from the parsed [`EventBatch`] — while serialized outputs
//! accumulate in the task's egestion buffer.  Each operator keeps its own
//! [`StepStats`], preserved per-operator through the run report.
//!
//! The four paper pipelines are canonical chains
//! ([`crate::config::PipelineKind::canonical_spec`]) compiled by
//! [`StepFactory`](super::StepFactory); their output is byte-identical to
//! the legacy monolithic implementations (`rust/tests/chain_equivalence.rs`
//! proves it).  User operators plug in through the
//! [`OperatorRegistry`](super::OperatorRegistry) and the `pipeline:
//! {ops: [...]}` config spec.

use std::rc::Rc;

use super::{PipelineStep, StepStats, HLO_KEYS};
use crate::broker::Record;
use crate::config::{BenchConfig, CmpOp, OpSpec, PipelineSpec};
use crate::engine::window::{AggKind, LatePolicy, Pane, WindowTime};
use crate::engine::{EventBatch, EventTimeWindow, SlidingWindow, WatermarkTracker, WindowEmit};
use crate::runtime::{Input, Runtime, RuntimeFactory};
use crate::util::json::Json;
use crate::wgen::{EventFormat, SensorEvent};

/// The working set flowing between chained operators: one row per event
/// (count = 1, timestamp = generation time) or per window aggregate
/// (count = events aggregated, timestamp = window end).
#[derive(Clone, Debug, Default)]
pub struct RowBatch {
    pub keys: Vec<u32>,
    pub vals: Vec<f32>,
    pub ts: Vec<u64>,
    pub counts: Vec<u64>,
}

impl RowBatch {
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn clear(&mut self) {
        self.keys.clear();
        self.vals.clear();
        self.ts.clear();
        self.counts.clear();
    }

    pub fn push(&mut self, key: u32, val: f32, ts: u64, count: u64) {
        self.keys.push(key);
        self.vals.push(val);
        self.ts.push(ts);
        self.counts.push(count);
    }

    /// Append every row of `other` (exchange drain path).
    pub fn extend_from(&mut self, other: &RowBatch) {
        self.keys.extend_from_slice(&other.keys);
        self.vals.extend_from_slice(&other.vals);
        self.ts.extend_from_slice(&other.ts);
        self.counts.extend_from_slice(&other.counts);
    }

    /// Reload from a parsed event batch (clears first).
    pub fn load_events(&mut self, batch: &EventBatch) {
        self.clear();
        self.keys.extend_from_slice(&batch.ids);
        self.vals.extend_from_slice(&batch.temps);
        self.ts.extend_from_slice(&batch.gen_ts);
        self.counts.resize(batch.len(), 1);
    }

    /// In-place compaction keeping rows where `keep(key, val)` is true.
    pub fn retain(&mut self, mut keep: impl FnMut(u32, f32) -> bool) {
        let mut w = 0;
        for r in 0..self.len() {
            if keep(self.keys[r], self.vals[r]) {
                if w != r {
                    self.keys[w] = self.keys[r];
                    self.vals[w] = self.vals[r];
                    self.ts[w] = self.ts[r];
                    self.counts[w] = self.counts[r];
                }
                w += 1;
            }
        }
        self.truncate(w);
    }

    /// In-place gather of the rows at `keep` (strictly ascending indices).
    pub fn select(&mut self, keep: &[usize]) {
        for (w, &r) in keep.iter().enumerate() {
            debug_assert!(w <= r, "select indices must be ascending");
            self.keys[w] = self.keys[r];
            self.vals[w] = self.vals[r];
            self.ts[w] = self.ts[r];
            self.counts[w] = self.counts[r];
        }
        self.truncate(keep.len());
    }

    fn truncate(&mut self, n: usize) {
        self.keys.truncate(n);
        self.vals.truncate(n);
        self.ts.truncate(n);
        self.counts.truncate(n);
    }
}

/// One operator in a chain, thread-confined like the chain itself.
///
/// `apply` transforms the rows in place (filters compact, maps rewrite
/// values, windows consume events and emit aggregates) and may push
/// serialized records into the egestion buffer.  `finish` is the
/// end-of-stream hook: the default forwards pending upstream rows through
/// `apply`, stateful operators additionally flush their state so the
/// emissions flow through the operators downstream.
pub trait Operator {
    fn name(&self) -> &str;

    /// True for operators that move raw broker records without parsing
    /// (the pass-through baseline).  Such an operator must be alone in its
    /// chain; the chain then skips parsing entirely.
    fn forwards_raw(&self) -> bool {
        false
    }

    fn apply(
        &mut self,
        now_micros: u64,
        rows: &mut RowBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String>;

    /// Raw-record path, only called when [`Operator::forwards_raw`] is true.
    fn apply_raw(&mut self, _now_micros: u64, _records: &[Record], _out: &mut Vec<Record>)
        -> Result<(), String> {
        Err(format!("operator '{}' does not forward raw records", self.name()))
    }

    fn finish(
        &mut self,
        now_micros: u64,
        rows: &mut RowBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.apply(now_micros, rows, out)
    }

    /// Exchange hook: called once at chain compile time when this
    /// operator's stage is fed by a keyed exchange boundary instead of the
    /// local parse path.  Event-time windows switch their watermark source
    /// from per-row observation to the exchange's min-merged frontier
    /// ([`Operator::note_watermark`]); everything else ignores it.
    fn set_exchange_input(&mut self, _fed_by_exchange: bool) {}

    /// Exchange hook: the boundary's safe frontier (min over live
    /// upstream frontiers), delivered before every `apply` on an
    /// exchange-fed stage.
    fn note_watermark(&mut self, _frontier_micros: u64) {}

    /// The timestamp frontier this operator has emitted through, when it
    /// gates downstream progress (windows report their finalized
    /// boundary); `None` for operators that forward their input frontier
    /// unchanged.
    fn out_frontier(&self) -> Option<u64> {
        None
    }

    /// Serialize this operator's mutable state for an aligned checkpoint.
    /// Stateless operators (and operators whose only state is per-batch
    /// scratch) return `Json::Null` — there is nothing to restore.
    /// Counters in [`StepStats`] are deliberately excluded: a restored run
    /// starts its counters at zero and the recovery driver reconciles the
    /// totals against the checkpoint's recorded intake.
    fn snapshot(&self) -> Json {
        Json::Null
    }

    /// Restore state captured by [`Operator::snapshot`] on a freshly
    /// compiled operator of the same spec.  Must reject (with a readable
    /// error, never a panic) state whose shape does not match.
    fn restore(&mut self, _state: &Json) -> Result<(), String> {
        Ok(())
    }

    fn stats(&self) -> StepStats;
}

// --- checkpoint state encoding -----------------------------------------------
//
// f32 state is encoded as raw bit patterns (`Json::Int` of `to_bits`), not
// decimal numbers: the JSON writer renders non-finite floats as `null`, and
// extrema panes legitimately hold ±inf sentinels.  Bit patterns also make
// the snapshot → restore round trip exactly lossless, which the
// byte-identical equivalence tests depend on.

fn f32s_to_json(vals: &[f32]) -> Json {
    Json::Arr(vals.iter().map(|v| Json::Int(v.to_bits() as i64)).collect())
}

fn f32s_from_json(j: &Json, what: &str) -> Result<Vec<f32>, String> {
    let arr = j
        .as_arr()
        .ok_or_else(|| format!("checkpoint state: '{what}' is not an array"))?;
    arr.iter()
        .map(|v| {
            v.as_i64()
                .map(|bits| f32::from_bits(bits as u32))
                .ok_or_else(|| format!("checkpoint state: '{what}' holds a non-integer bit pattern"))
        })
        .collect()
}

fn state_u64(j: &Json, key: &str, what: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(|v| v.as_i64())
        .map(|v| v as u64)
        .ok_or_else(|| format!("checkpoint state: '{what}' is missing integer field '{key}'"))
}

fn pane_to_json(p: &Pane) -> Json {
    let mut o = Json::obj();
    o.set("start", Json::Int(p.start_micros as i64));
    o.set("sum", f32s_to_json(&p.sum));
    o.set("cnt", f32s_to_json(&p.cnt));
    o.set("min", f32s_to_json(&p.min));
    o.set("max", f32s_to_json(&p.max));
    o
}

fn pane_from_json(j: &Json) -> Result<Pane, String> {
    let missing = |k: &str| format!("checkpoint state: pane is missing field '{k}'");
    Ok(Pane {
        start_micros: state_u64(j, "start", "pane")?,
        sum: f32s_from_json(j.get("sum").ok_or_else(|| missing("sum"))?, "pane.sum")?,
        cnt: f32s_from_json(j.get("cnt").ok_or_else(|| missing("cnt"))?, "pane.cnt")?,
        min: f32s_from_json(j.get("min").ok_or_else(|| missing("min"))?, "pane.min")?,
        max: f32s_from_json(j.get("max").ok_or_else(|| missing("max"))?, "pane.max")?,
    })
}

fn panes_to_json(panes: &[Pane]) -> Json {
    Json::Arr(panes.iter().map(pane_to_json).collect())
}

fn panes_from_json(j: &Json, what: &str) -> Result<Vec<Pane>, String> {
    j.as_arr()
        .ok_or_else(|| format!("checkpoint state: '{what}' is not an array"))?
        .iter()
        .map(pane_from_json)
        .collect()
}

/// Compute backend handle for HLO-capable operators; the `Rc` lets every
/// operator in a chain share one thread-confined PJRT runtime.
pub enum OpCompute {
    Hlo(Rc<Runtime>),
    Native,
}

// --- built-in operators ------------------------------------------------------

/// Pass-through: forwards raw broker records (payload `Arc`s, no copy).
#[derive(Default)]
pub struct ForwardOp {
    stats: StepStats,
}

impl Operator for ForwardOp {
    fn name(&self) -> &str {
        "forward"
    }

    fn forwards_raw(&self) -> bool {
        true
    }

    fn apply(
        &mut self,
        _now_micros: u64,
        _rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        Err("forward runs on the raw-record path".into())
    }

    fn apply_raw(
        &mut self,
        _now_micros: u64,
        records: &[Record],
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.stats.events_in += records.len() as u64;
        self.stats.events_out += records.len() as u64;
        out.extend(records.iter().cloned());
        Ok(())
    }

    fn finish(
        &mut self,
        _now_micros: u64,
        _rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

/// Predicate filter over row values.
pub struct FilterOp {
    cmp: CmpOp,
    value: f32,
    stats: StepStats,
}

impl FilterOp {
    pub fn new(cmp: CmpOp, value: f32) -> Self {
        Self {
            cmp,
            value,
            stats: StepStats::default(),
        }
    }
}

impl Operator for FilterOp {
    fn name(&self) -> &str {
        "filter"
    }

    fn apply(
        &mut self,
        _now_micros: u64,
        rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.stats.events_in += rows.len() as u64;
        let (cmp, value) = (self.cmp, self.value);
        rows.retain(|_, v| cmp.eval(v, value));
        self.stats.events_out += rows.len() as u64;
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

/// Affine projection of the value: `v * scale + offset`.
pub struct MapOp {
    scale: f32,
    offset: f32,
    stats: StepStats,
}

impl MapOp {
    pub fn new(scale: f32, offset: f32) -> Self {
        Self {
            scale,
            offset,
            stats: StepStats::default(),
        }
    }
}

impl Operator for MapOp {
    fn name(&self) -> &str {
        "map"
    }

    fn apply(
        &mut self,
        _now_micros: u64,
        rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.stats.events_in += rows.len() as u64;
        for v in &mut rows.vals {
            *v = *v * self.scale + self.offset;
        }
        self.stats.events_out += rows.len() as u64;
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

/// Shuffle-style regrouping: `key % modulo`.
pub struct KeyByOp {
    modulo: u32,
    stats: StepStats,
}

impl KeyByOp {
    pub fn new(modulo: u32) -> Self {
        assert!(modulo > 0);
        Self {
            modulo,
            stats: StepStats::default(),
        }
    }
}

impl Operator for KeyByOp {
    fn name(&self) -> &str {
        "keyby"
    }

    fn apply(
        &mut self,
        _now_micros: u64,
        rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.stats.events_in += rows.len() as u64;
        for k in &mut rows.keys {
            *k %= self.modulo;
        }
        self.stats.events_out += rows.len() as u64;
        Ok(())
    }

    // Pure per-row arithmetic: no cross-batch state, so the default Null
    // snapshot / no-op restore is this operator's checkpoint contract.
    fn snapshot(&self) -> Json {
        Json::Null
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

/// The paper's CPU-intensive transform: °C → °F, counting threshold
/// alerts; HLO (`cpu_pipeline_step`) or native compute.
pub struct CpuTransformOp {
    compute: OpCompute,
    threshold_f: f32,
    stats: StepStats,
    temps_pad: Vec<f32>,
}

impl CpuTransformOp {
    pub fn new(compute: OpCompute, threshold_f: f32) -> Self {
        Self {
            compute,
            threshold_f,
            stats: StepStats::default(),
            temps_pad: Vec::new(),
        }
    }
}

impl Operator for CpuTransformOp {
    fn name(&self) -> &str {
        "cpu_transform"
    }

    fn apply(
        &mut self,
        _now_micros: u64,
        rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        let n = rows.len();
        if n == 0 {
            return Ok(());
        }
        self.stats.events_in += n as u64;
        match &self.compute {
            OpCompute::Hlo(rt) => {
                let thresh = [self.threshold_f];
                let mut off = 0;
                while off < n {
                    let remaining = n - off;
                    let artifact = rt.select("cpu_pipeline_step", remaining)?;
                    let b = artifact.batch;
                    let name = artifact.name.clone();
                    let take = b.min(remaining);
                    self.temps_pad.clear();
                    self.temps_pad.extend_from_slice(&rows.vals[off..off + take]);
                    self.temps_pad.resize(b, 0.0);
                    let outs = rt.execute_f32(
                        &name,
                        &[Input::F32(&self.temps_pad), Input::F32(&thresh)],
                    )?;
                    self.stats.hlo_calls += 1;
                    let mut it = outs.into_iter();
                    let fahr = it.next().ok_or("missing fahr output")?;
                    let alerts = it.next().ok_or("missing alerts output")?;
                    rows.vals[off..off + take].copy_from_slice(&fahr[..take]);
                    self.stats.alerts +=
                        alerts[..take].iter().filter(|&&a| a > 0.5).count() as u64;
                    off += take;
                }
            }
            OpCompute::Native => {
                for v in &mut rows.vals {
                    *v = *v * 9.0 / 5.0 + 32.0;
                    if *v > self.threshold_f {
                        self.stats.alerts += 1;
                    }
                }
            }
        }
        self.stats.events_out += n as u64;
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

/// Keyed sliding-window aggregation: consumes event rows, emits one
/// aggregate row per `(window, key)` at every slide boundary.  The
/// per-batch state update runs through the `mem_pipeline_step` HLO
/// artifact for sum/cnt aggregators, natively otherwise.
pub struct WindowAggregateOp {
    compute: OpCompute,
    window: SlidingWindow,
    keys: usize,
    stats: StepStats,
    ids_pad: Vec<i32>,
    temps_pad: Vec<f32>,
}

impl WindowAggregateOp {
    pub fn new(
        compute: OpCompute,
        agg: AggKind,
        sensors: usize,
        window_micros: u64,
        slide_micros: u64,
        start_micros: u64,
    ) -> Self {
        // The AOT artifacts carry K = 1024 key slots; wider configurations
        // (and extrema aggregators) keep state natively.
        let keys = match &compute {
            OpCompute::Hlo(_) => sensors.min(HLO_KEYS),
            OpCompute::Native => sensors,
        };
        Self {
            compute,
            window: SlidingWindow::with_agg(keys, window_micros, slide_micros, start_micros, agg),
            keys,
            stats: StepStats::default(),
            ids_pad: Vec::new(),
            temps_pad: Vec::new(),
        }
    }

    pub fn agg(&self) -> AggKind {
        self.window.agg()
    }

    fn accumulate(&mut self, rows: &RowBatch) -> Result<(), String> {
        match &self.compute {
            OpCompute::Hlo(rt) => {
                let mut off = 0;
                while off < rows.len() {
                    let remaining = rows.len() - off;
                    let artifact = rt.select("mem_pipeline_step", remaining)?;
                    let b = artifact.batch;
                    let k = artifact.keys;
                    let name = artifact.name.clone();
                    debug_assert_eq!(k, HLO_KEYS);
                    let take = b.min(remaining);
                    self.ids_pad.clear();
                    self.temps_pad.clear();
                    for i in off..off + take {
                        // Out-of-range keys (> K) become padding too.
                        let id = rows.keys[i] as usize;
                        self.ids_pad
                            .push(if id < self.keys { id as i32 } else { k as i32 });
                        self.temps_pad.push(rows.vals[i]);
                    }
                    // Pad with id == K so padded slots drop out of the
                    // one-hot mask inside the kernel.
                    self.ids_pad.resize(b, k as i32);
                    self.temps_pad.resize(b, 0.0);
                    let pane = self.window.current_pane();
                    let mut sum_state = pane.sum.clone();
                    let mut cnt_state = pane.cnt.clone();
                    sum_state.resize(k, 0.0);
                    cnt_state.resize(k, 0.0);
                    let outs = rt.execute_f32(
                        &name,
                        &[
                            Input::I32(&self.ids_pad),
                            Input::F32(&self.temps_pad),
                            Input::F32(&sum_state),
                            Input::F32(&cnt_state),
                        ],
                    )?;
                    self.stats.hlo_calls += 1;
                    let mut it = outs.into_iter();
                    let mut new_sum = it.next().ok_or("missing sum output")?;
                    let mut new_cnt = it.next().ok_or("missing cnt output")?;
                    new_sum.truncate(self.keys);
                    new_cnt.truncate(self.keys);
                    self.window.store_state(new_sum, new_cnt);
                    off += take;
                }
                Ok(())
            }
            OpCompute::Native => {
                self.window.accumulate_native(&rows.keys, &rows.vals);
                Ok(())
            }
        }
    }

    /// Replace the rows with the emitted aggregates.
    fn emit_rows(&mut self, emits: Vec<WindowEmit>, rows: &mut RowBatch) {
        emit_aggregate_rows(emits, rows, &mut self.stats);
    }
}

/// Replace `rows` with one row per emitted `(window, key)` aggregate,
/// updating the owning operator's emission counters.  Shared by the
/// processing-time and event-time window operators.
fn emit_aggregate_rows(emits: Vec<WindowEmit>, rows: &mut RowBatch, stats: &mut StepStats) {
    rows.clear();
    for e in emits {
        stats.window_emits += 1;
        for &(key, value, count) in &e.aggregates {
            rows.push(key, value, e.end_micros, count);
            stats.events_out += 1;
        }
    }
}

impl Operator for WindowAggregateOp {
    fn name(&self) -> &str {
        "window"
    }

    fn apply(
        &mut self,
        now_micros: u64,
        rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        if !rows.is_empty() {
            self.stats.events_in += rows.len() as u64;
            self.accumulate(rows)?;
        }
        let emits = self.window.advance(now_micros);
        self.emit_rows(emits, rows);
        Ok(())
    }

    fn finish(
        &mut self,
        now_micros: u64,
        rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        // Accumulate rows still pending from upstream flushes, then drain
        // boundaries reached by `now` and force the final pane closed so
        // short runs still emit their window.
        if !rows.is_empty() {
            self.stats.events_in += rows.len() as u64;
            self.accumulate(rows)?;
        }
        let mut emits = self.window.advance(now_micros);
        emits.extend(self.window.flush());
        self.emit_rows(emits, rows);
        Ok(())
    }

    fn out_frontier(&self) -> Option<u64> {
        // The open pane starts where the last emitted boundary ended:
        // every aggregate with end <= this has been emitted.
        Some(self.window.current_pane().start_micros)
    }

    fn snapshot(&self) -> Json {
        let (closed, current) = self.window.export_state();
        let mut o = Json::obj();
        o.set("closed", panes_to_json(&closed));
        o.set("current", pane_to_json(&current));
        o
    }

    fn restore(&mut self, state: &Json) -> Result<(), String> {
        let closed = panes_from_json(
            state
                .get("closed")
                .ok_or("checkpoint state: window is missing 'closed'")?,
            "window.closed",
        )?;
        let current = pane_from_json(
            state
                .get("current")
                .ok_or("checkpoint state: window is missing 'current'")?,
        )?;
        self.window.import_state(closed, current)
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

/// Keyed sliding-window aggregation over **event time**: rows are
/// assigned to panes by their generation timestamp, a bounded-disorder
/// [`WatermarkTracker`] (advanced once per [`RowBatch`]) drives window
/// finalization, and records behind the watermark are routed through the
/// configured [`LatePolicy`].  Runs native-only: pane assignment is
/// data-dependent per record, which the single-state `mem_pipeline_step`
/// HLO artifact cannot express.
pub struct EventTimeWindowOp {
    tracker: WatermarkTracker,
    window: EventTimeWindow,
    stats: StepStats,
    /// When fed by a keyed exchange, the watermark follows the boundary's
    /// min-merged safe frontier instead of locally observed row
    /// timestamps — a fast local sub-stream must not outrun rows still in
    /// flight from a slower upstream task.
    exchange_fed: bool,
    external_frontier: u64,
}

impl EventTimeWindowOp {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        agg: AggKind,
        sensors: usize,
        window_micros: u64,
        slide_micros: u64,
        start_micros: u64,
        watermark_bound_micros: u64,
        allowed_lateness_micros: u64,
        policy: LatePolicy,
    ) -> Self {
        Self {
            tracker: WatermarkTracker::new(watermark_bound_micros),
            window: EventTimeWindow::new(
                sensors,
                window_micros,
                slide_micros,
                start_micros,
                agg,
                allowed_lateness_micros,
                policy,
            ),
            stats: StepStats::default(),
            exchange_fed: false,
            external_frontier: 0,
        }
    }

    pub fn agg(&self) -> AggKind {
        self.window.agg()
    }

    fn ingest(&mut self, now_micros: u64, rows: &mut RowBatch) -> Vec<WindowEmit> {
        if self.exchange_fed && self.external_frontier > 0 && self.external_frontier < u64::MAX {
            // Exchange-fed: the boundary's safe frontier drives the
            // watermark; per-row observation would let one fast upstream
            // finalize windows whose rows are still queued elsewhere.
            // Frontier 0 = no upstream published yet (no signal); MAX =
            // every upstream finished — `finish`'s flush finalizes the
            // remaining panes, and observing MAX here would fast-forward
            // the window to a far-future empty emission instead.
            self.tracker.observe(self.external_frontier);
        }
        if !rows.is_empty() {
            self.stats.events_in += rows.len() as u64;
            if !self.exchange_fed {
                self.tracker.observe_batch(&rows.ts);
            }
            self.window.accumulate(&rows.keys, &rows.vals, &rows.ts);
        }
        let wm = self.tracker.advance();
        let emits = self.window.advance(wm);
        // The window holds the cumulative truth; mirror, don't add.
        self.stats.late_events = self.window.late_events();
        self.stats.dropped_events = self.window.dropped_events();
        self.stats.watermark_lag_micros = self
            .stats
            .watermark_lag_micros
            .max(self.tracker.lag_at(now_micros));
        emits
    }
}

impl Operator for EventTimeWindowOp {
    fn name(&self) -> &str {
        "window"
    }

    fn apply(
        &mut self,
        now_micros: u64,
        rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        let emits = self.ingest(now_micros, rows);
        emit_aggregate_rows(emits, rows, &mut self.stats);
        Ok(())
    }

    fn finish(
        &mut self,
        now_micros: u64,
        rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        let mut emits = self.ingest(now_micros, rows);
        emits.extend(self.window.flush());
        emit_aggregate_rows(emits, rows, &mut self.stats);
        Ok(())
    }

    fn set_exchange_input(&mut self, fed_by_exchange: bool) {
        self.exchange_fed = fed_by_exchange;
    }

    fn note_watermark(&mut self, frontier_micros: u64) {
        self.external_frontier = self.external_frontier.max(frontier_micros);
    }

    fn out_frontier(&self) -> Option<u64> {
        Some(self.window.emitted_through())
    }

    fn snapshot(&self) -> Json {
        let (panes, next_end, watermark, late, dropped) = self.window.export_state();
        let (max_ts, wm, seen) = self.tracker.export_state();
        let mut o = Json::obj();
        o.set("panes", panes_to_json(&panes));
        o.set("next_end", Json::Int(next_end as i64));
        o.set("watermark", Json::Int(watermark as i64));
        o.set("late_events", Json::Int(late as i64));
        o.set("dropped_events", Json::Int(dropped as i64));
        o.set("tracker_max_ts", Json::Int(max_ts as i64));
        o.set("tracker_watermark", Json::Int(wm as i64));
        o.set("tracker_seen", Json::Bool(seen));
        o.set("external_frontier", Json::Int(self.external_frontier as i64));
        o
    }

    fn restore(&mut self, state: &Json) -> Result<(), String> {
        let panes = panes_from_json(
            state
                .get("panes")
                .ok_or("checkpoint state: event-time window is missing 'panes'")?,
            "event_time.panes",
        )?;
        let what = "event_time";
        self.window.import_state(
            panes,
            state_u64(state, "next_end", what)?,
            state_u64(state, "watermark", what)?,
            state_u64(state, "late_events", what)?,
            state_u64(state, "dropped_events", what)?,
        )?;
        let seen = state
            .get("tracker_seen")
            .and_then(|v| v.as_bool())
            .ok_or("checkpoint state: event_time is missing bool field 'tracker_seen'")?;
        self.tracker.import_state(
            state_u64(state, "tracker_max_ts", what)?,
            state_u64(state, "tracker_watermark", what)?,
            seen,
        );
        self.external_frontier = state_u64(state, "external_frontier", what)?;
        // The stats mirror of the window's cumulative late/dropped truth
        // resynchronizes on the next ingest; nothing else to restore.
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

/// Keep the `k` largest aggregates per window (rows grouped by their
/// window-end timestamp).  Ties break toward the smaller key, so the
/// selection is deterministic.
pub struct TopKOp {
    k: usize,
    stats: StepStats,
    idx: Vec<usize>,
    kept: Vec<usize>,
}

impl TopKOp {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        Self {
            k,
            stats: StepStats::default(),
            idx: Vec::new(),
            kept: Vec::new(),
        }
    }
}

impl Operator for TopKOp {
    fn name(&self) -> &str {
        "topk"
    }

    fn apply(
        &mut self,
        _now_micros: u64,
        rows: &mut RowBatch,
        _out: &mut Vec<Record>,
    ) -> Result<(), String> {
        let n = rows.len();
        if n == 0 {
            return Ok(());
        }
        self.stats.events_in += n as u64;
        self.idx.clear();
        self.idx.extend(0..n);
        let (ts, vals, keys) = (&rows.ts, &rows.vals, &rows.keys);
        self.idx.sort_by(|&a, &b| {
            ts[a]
                .cmp(&ts[b])
                .then_with(|| {
                    vals[b]
                        .partial_cmp(&vals[a])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .then_with(|| keys[a].cmp(&keys[b]))
        });
        self.kept.clear();
        let mut cur_ts = None;
        let mut taken = 0usize;
        for &i in &self.idx {
            if cur_ts != Some(ts[i]) {
                cur_ts = Some(ts[i]);
                taken = 0;
            }
            if taken < self.k {
                self.kept.push(i);
                taken += 1;
            }
        }
        // Restore original (window, key) emission order.
        self.kept.sort_unstable();
        rows.select(&self.kept);
        self.stats.events_out += rows.len() as u64;
        Ok(())
    }

    // `idx`/`kept` are per-apply scratch, rebuilt from each batch: the
    // selection holds no cross-batch state, so Null is the full snapshot.
    fn snapshot(&self) -> Json {
        Json::Null
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

/// Serialize rows as sensor events for the egestion topic; rows pass
/// through unchanged so a window may follow (the fused shape).
pub struct EmitEventsOp {
    event_bytes: usize,
    stats: StepStats,
    wire: Vec<u8>,
}

impl EmitEventsOp {
    pub fn new(event_bytes: usize) -> Self {
        Self {
            event_bytes,
            stats: StepStats::default(),
            wire: Vec::new(),
        }
    }
}

impl Operator for EmitEventsOp {
    fn name(&self) -> &str {
        "emit_events"
    }

    fn apply(
        &mut self,
        _now_micros: u64,
        rows: &mut RowBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.stats.events_in += rows.len() as u64;
        let fmt = if self.event_bytes < 40 {
            EventFormat::Csv
        } else {
            EventFormat::Json
        };
        for i in 0..rows.len() {
            let ev = SensorEvent {
                ts_micros: rows.ts[i],
                sensor_id: rows.keys[i],
                temp_c: rows.vals[i],
            };
            ev.serialize_into(fmt, self.event_bytes, &mut self.wire);
            out.push(Record::new(rows.keys[i], self.wire.as_slice(), rows.ts[i]));
            self.stats.events_out += 1;
        }
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

/// Serialize aggregate rows as compact JSON records
/// (`{"win":…,"id":…,"<agg>":…,"n":…}`); `avg` for mean keeps the paper
/// pipeline's wire format byte-stable.
pub struct EmitAggregatesOp {
    field: &'static str,
    stats: StepStats,
}

impl EmitAggregatesOp {
    pub fn new(agg: AggKind) -> Self {
        Self {
            field: agg.field(),
            stats: StepStats::default(),
        }
    }
}

impl Operator for EmitAggregatesOp {
    fn name(&self) -> &str {
        "emit_aggregates"
    }

    fn apply(
        &mut self,
        _now_micros: u64,
        rows: &mut RowBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.stats.events_in += rows.len() as u64;
        for i in 0..rows.len() {
            let payload = format!(
                "{{\"win\":{},\"id\":{},\"{}\":{:.3},\"n\":{}}}",
                rows.ts[i], rows.keys[i], self.field, rows.vals[i], rows.counts[i]
            );
            out.push(Record::new(rows.keys[i], payload.into_bytes(), rows.ts[i]));
            self.stats.events_out += 1;
        }
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

// --- the chain ---------------------------------------------------------------

/// A fused chain of operators, one per engine-task thread, implementing
/// the engine-facing [`PipelineStep`] contract.
pub struct Chain {
    label: String,
    ops: Vec<Box<dyn Operator>>,
    rows: RowBatch,
    raw: bool,
    events_out: u64,
}

impl Chain {
    /// Assemble a chain from already-built operators (tests, benches, and
    /// the registry path).  `label` is the pipeline name reported by
    /// [`PipelineStep::name`].
    pub fn from_ops(label: impl Into<String>, ops: Vec<Box<dyn Operator>>) -> Result<Chain, String> {
        if ops.is_empty() {
            return Err("operator chain is empty".into());
        }
        let raw = ops[0].forwards_raw();
        if raw && ops.len() > 1 {
            return Err("a raw-forwarding operator must be the only one in its chain".into());
        }
        Ok(Chain {
            label: label.into(),
            ops,
            rows: RowBatch::default(),
            raw,
            events_out: 0,
        })
    }

    /// Compile a declarative spec into a chain.  One shared PJRT runtime
    /// backs every HLO-capable operator; when `runtime_factory` is present
    /// but its artifacts are missing, compilation fails with the same
    /// readable error the monolithic factory produced.
    pub fn compile(
        cfg: &BenchConfig,
        spec: &PipelineSpec,
        label: impl Into<String>,
        runtime_factory: Option<&RuntimeFactory>,
        registry: Option<&super::OperatorRegistry>,
        start_micros: u64,
    ) -> Result<Chain, String> {
        Chain::compile_with_agg(cfg, spec, label, runtime_factory, registry, start_micros, None)
    }

    /// [`Chain::compile`] with an inherited aggregator for
    /// `emit_aggregates` ops whose window lives in an upstream exchange
    /// stage (the staged compiler passes the full spec's last window agg).
    #[allow(clippy::too_many_arguments)]
    pub fn compile_with_agg(
        cfg: &BenchConfig,
        spec: &PipelineSpec,
        label: impl Into<String>,
        runtime_factory: Option<&RuntimeFactory>,
        registry: Option<&super::OperatorRegistry>,
        start_micros: u64,
        inherited_agg: Option<AggKind>,
    ) -> Result<Chain, String> {
        // Which HLO programs does this chain need?
        let mut programs: Vec<&'static str> = Vec::new();
        for op in &spec.ops {
            match op {
                OpSpec::CpuTransform => programs.push("cpu_pipeline_step"),
                // Event-time windows accumulate natively (pane assignment
                // is per-record data-dependent), so only processing-time
                // sum/cnt windows need the keyed-state artifact.
                OpSpec::Window { agg, time, .. }
                    if *time == WindowTime::Processing && agg.uses_sum_cnt() =>
                {
                    programs.push("mem_pipeline_step")
                }
                _ => {}
            }
        }
        programs.sort_unstable();
        programs.dedup();
        let runtime: Option<Rc<Runtime>> = match runtime_factory {
            Some(f) if !programs.is_empty() => {
                if !f.available() {
                    return Err(format!(
                        "artifacts not found in {} — run `make artifacts`",
                        f.dir().display()
                    ));
                }
                let rt = f.create()?;
                // Compile every batch-size variant up front: PJRT
                // compilation must never land on the first hot batch
                // (it would poison the latency tail).
                for p in &programs {
                    rt.warm(p)?;
                }
                Some(Rc::new(rt))
            }
            _ => None,
        };
        let hlo = |needed: bool| -> OpCompute {
            match (&runtime, needed) {
                (Some(rt), true) => OpCompute::Hlo(rt.clone()),
                _ => OpCompute::Native,
            }
        };

        let ctx = super::OpContext {
            config: cfg,
            start_micros,
        };
        let mut ops: Vec<Box<dyn Operator>> = Vec::with_capacity(spec.ops.len());
        for (i, op) in spec.ops.iter().enumerate() {
            ops.push(match op {
                OpSpec::Forward => Box::new(ForwardOp::default()),
                OpSpec::Filter { cmp, value } => Box::new(FilterOp::new(*cmp, *value)),
                OpSpec::Map { scale, offset } => Box::new(MapOp::new(*scale, *offset)),
                OpSpec::CpuTransform => {
                    Box::new(CpuTransformOp::new(hlo(true), cfg.engine.threshold_f))
                }
                OpSpec::KeyBy { modulo, .. } => Box::new(KeyByOp::new(*modulo)),
                OpSpec::Window {
                    agg,
                    window_micros,
                    slide_micros,
                    time,
                    allowed_lateness_micros,
                    late_policy,
                    ..
                } => {
                    let w = if *window_micros > 0 {
                        *window_micros
                    } else {
                        cfg.engine.window_micros
                    };
                    let s = if *slide_micros > 0 {
                        *slide_micros
                    } else {
                        cfg.engine.slide_micros
                    };
                    match time {
                        WindowTime::Processing => Box::new(WindowAggregateOp::new(
                            hlo(agg.uses_sum_cnt()),
                            *agg,
                            cfg.workload.sensors as usize,
                            w,
                            s,
                            start_micros,
                        )) as Box<dyn Operator>,
                        WindowTime::Event => {
                            // Watermark bound inherit chain (single
                            // definition: OpSpec::event_watermark_bound):
                            // explicit spec value, else max(disorder
                            // lateness, slide) — the slide floor matters
                            // when disorder comes from shuffle/stragglers
                            // alone (lateness 0), where a tiny bound would
                            // drop most of the reordered stream.
                            let bound = op
                                .event_watermark_bound(cfg)
                                .expect("event-time window resolves a bound");
                            Box::new(EventTimeWindowOp::new(
                                *agg,
                                cfg.workload.sensors as usize,
                                w,
                                s,
                                start_micros,
                                bound,
                                *allowed_lateness_micros,
                                *late_policy,
                            ))
                        }
                    }
                }
                OpSpec::TopK { k, .. } => Box::new(TopKOp::new(*k)),
                OpSpec::EmitEvents => Box::new(EmitEventsOp::new(cfg.workload.event_bytes)),
                OpSpec::EmitAggregates => Box::new(EmitAggregatesOp::new(
                    spec.window_agg_before(i)
                        .or(inherited_agg)
                        .unwrap_or(AggKind::Mean),
                )),
                OpSpec::Custom { name, params } => {
                    let reg = registry.ok_or_else(|| {
                        format!(
                            "pipeline spec uses operator '{name}', which is not a \
                             built-in (forward, filter, map, cpu_transform, keyby, \
                             window, topk, emit_events, emit_aggregates) and no \
                             OperatorRegistry was provided — check for a misspelled \
                             built-in, or register '{name}' and build the factory \
                             with StepFactory::with_registry"
                        )
                    })?;
                    reg.build(name, params, &ctx)?
                }
            });
        }
        Chain::from_ops(label, ops)
    }

    /// Per-operator names, in chain order.
    pub fn op_names(&self) -> Vec<&str> {
        self.ops.iter().map(|o| o.name()).collect()
    }

    /// Run the operators over an externally supplied row working set (the
    /// staged-exchange entry point: downstream stages receive rows from
    /// the fabric, not from a parsed [`EventBatch`]).  `rows` is
    /// transformed in place; serialized outputs land in `out`.
    pub fn process_rows(
        &mut self,
        now_micros: u64,
        rows: &mut RowBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        let out_before = out.len();
        for op in self.ops.iter_mut() {
            op.apply(now_micros, rows, out)?;
        }
        self.events_out += (out.len() - out_before) as u64;
        Ok(())
    }

    /// End-of-stream flush over an externally supplied working set
    /// (stateful operators drain through the downstream ops).
    pub fn finish_rows(
        &mut self,
        now_micros: u64,
        rows: &mut RowBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        let out_before = out.len();
        for op in self.ops.iter_mut() {
            op.finish(now_micros, rows, out)?;
        }
        self.events_out += (out.len() - out_before) as u64;
        Ok(())
    }

    /// Deliver the exchange boundary's safe frontier to every operator
    /// (event-time windows advance their watermark from it).
    pub fn note_watermark(&mut self, frontier_micros: u64) {
        for op in self.ops.iter_mut() {
            op.note_watermark(frontier_micros);
        }
    }

    /// Mark this chain as fed by a keyed exchange boundary (switches
    /// event-time windows to the external watermark source).
    pub fn mark_exchange_fed(&mut self) {
        for op in self.ops.iter_mut() {
            op.set_exchange_input(true);
        }
    }

    /// The frontier this chain has emitted through, given the frontier of
    /// its input: windows narrow it to their finalized boundary,
    /// transparent operators pass it along.
    pub fn out_frontier(&self, input_frontier_micros: u64) -> u64 {
        let mut f = input_frontier_micros;
        for op in &self.ops {
            if let Some(v) = op.out_frontier() {
                f = v;
            }
        }
        f
    }

    /// Serialize every operator's state, tagged with the operator name so
    /// [`Chain::restore_ops`] can verify the checkpoint was taken from a
    /// chain of the same shape.
    pub fn snapshot_ops(&self) -> Json {
        Json::Arr(
            self.ops
                .iter()
                .map(|op| {
                    let mut o = Json::obj();
                    o.set("op", Json::Str(op.name().to_string()));
                    o.set("state", op.snapshot());
                    o
                })
                .collect(),
        )
    }

    /// Restore state captured by [`Chain::snapshot_ops`] into a freshly
    /// compiled chain.  Rejects (readable error, never a panic) a
    /// checkpoint whose operator sequence does not match this chain.
    pub fn restore_ops(&mut self, state: &Json) -> Result<(), String> {
        let arr = state
            .as_arr()
            .ok_or("checkpoint state: chain state is not an array")?;
        if arr.len() != self.ops.len() {
            return Err(format!(
                "checkpoint state holds {} operators but the pipeline has {} — \
                 the checkpoint was taken from a different pipeline spec",
                arr.len(),
                self.ops.len()
            ));
        }
        for (op, entry) in self.ops.iter_mut().zip(arr) {
            let name = entry
                .get("op")
                .and_then(|v| v.as_str())
                .ok_or("checkpoint state: operator entry is missing 'op'")?;
            if name != op.name() {
                return Err(format!(
                    "checkpoint operator '{name}' does not match pipeline operator \
                     '{}' — the checkpoint was taken from a different pipeline spec",
                    op.name()
                ));
            }
            op.restore(entry.get("state").unwrap_or(&Json::Null))
                .map_err(|e| format!("restoring operator '{name}': {e}"))?;
        }
        Ok(())
    }
}

impl PipelineStep for Chain {
    fn name(&self) -> &str {
        &self.label
    }

    fn needs_parse(&self) -> bool {
        !self.raw
    }

    fn process(
        &mut self,
        now_micros: u64,
        records: &[Record],
        batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        if self.raw {
            let out_before = out.len();
            self.ops[0].apply_raw(now_micros, records, out)?;
            self.events_out += (out.len() - out_before) as u64;
        } else {
            let mut rows = std::mem::take(&mut self.rows);
            rows.load_events(batch);
            let res = self.process_rows(now_micros, &mut rows, out);
            self.rows = rows;
            res?;
        }
        Ok(())
    }

    fn finish(&mut self, now_micros: u64, out: &mut Vec<Record>) -> Result<(), String> {
        if !self.raw {
            let mut rows = std::mem::take(&mut self.rows);
            rows.clear();
            let res = self.finish_rows(now_micros, &mut rows, out);
            self.rows = rows;
            res?;
        }
        Ok(())
    }

    /// Whole-chain stats, shaped to match the monolithic pipelines:
    /// `events_in` is the first operator's intake, `events_out` the records
    /// the chain pushed to egestion, counters sum across operators.
    fn stats(&self) -> StepStats {
        let mut s = StepStats::default();
        for op in &self.ops {
            s.merge(&op.stats());
        }
        // The merge summed per-op intake/output; chain-level semantics
        // are the first op's intake and the records actually egested.
        s.events_in = self.ops.first().map(|o| o.stats().events_in).unwrap_or(0);
        s.events_out = self.events_out;
        s
    }

    fn operator_stats(&self) -> Vec<(String, StepStats)> {
        self.ops
            .iter()
            .map(|o| (o.name().to_string(), o.stats()))
            .collect()
    }

    fn snapshot(&self) -> Result<Json, String> {
        Ok(self.snapshot_ops())
    }

    fn restore(&mut self, state: &Json) -> Result<(), String> {
        self.restore_ops(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(keys: &[u32], vals: &[f32]) -> RowBatch {
        let mut r = RowBatch::default();
        for (i, (&k, &v)) in keys.iter().zip(vals).enumerate() {
            r.push(k, v, i as u64, 1);
        }
        r
    }

    #[test]
    fn filter_compacts_rows_in_place() {
        let mut f = FilterOp::new(CmpOp::Gt, 10.0);
        let mut r = rows(&[1, 2, 3, 4], &[5.0, 15.0, 10.0, 30.0]);
        let mut out = Vec::new();
        f.apply(0, &mut r, &mut out).unwrap();
        assert_eq!(r.keys, vec![2, 4]);
        assert_eq!(r.vals, vec![15.0, 30.0]);
        assert_eq!(r.ts, vec![1, 3]);
        let s = f.stats();
        assert_eq!(s.events_in, 4);
        assert_eq!(s.events_out, 2);
        assert!(out.is_empty());
    }

    #[test]
    fn map_applies_affine_transform() {
        let mut m = MapOp::new(1.8, 32.0);
        let mut r = rows(&[0, 1], &[0.0, 100.0]);
        let mut out = Vec::new();
        m.apply(0, &mut r, &mut out).unwrap();
        assert!((r.vals[0] - 32.0).abs() < 1e-6);
        assert!((r.vals[1] - 212.0).abs() < 1e-4);
    }

    #[test]
    fn keyby_regroups_keys() {
        let mut k = KeyByOp::new(4);
        let mut r = rows(&[0, 5, 9, 13], &[1.0; 4]);
        let mut out = Vec::new();
        k.apply(0, &mut r, &mut out).unwrap();
        assert_eq!(r.keys, vec![0, 1, 1, 1]);
    }

    #[test]
    fn cpu_transform_native_matches_formula_and_counts_alerts() {
        let mut op = CpuTransformOp::new(OpCompute::Native, 80.0);
        let mut r = rows(&[1, 2, 3], &[0.0, 100.0, -40.0]);
        let mut out = Vec::new();
        op.apply(0, &mut r, &mut out).unwrap();
        assert!((r.vals[0] - 32.0).abs() < 0.01);
        assert!((r.vals[1] - 212.0).abs() < 0.01);
        assert!((r.vals[2] + 40.0).abs() < 0.01);
        assert_eq!(op.stats().alerts, 1); // only 212°F > 80°F
    }

    #[test]
    fn window_consumes_events_and_emits_aggregate_rows() {
        let mut w = WindowAggregateOp::new(
            OpCompute::Native,
            AggKind::Mean,
            16,
            2_000_000,
            1_000_000,
            0,
        );
        let mut out = Vec::new();
        let mut r = rows(&[1, 1, 2], &[10.0, 20.0, 7.0]);
        w.apply(0, &mut r, &mut out).unwrap();
        assert!(r.is_empty(), "no boundary crossed yet → no aggregate rows");
        let mut r = RowBatch::default();
        w.apply(1_000_000, &mut r, &mut out).unwrap();
        assert_eq!(r.keys, vec![1, 2]);
        assert_eq!(r.vals, vec![15.0, 7.0]);
        assert_eq!(r.counts, vec![2, 1]);
        assert_eq!(r.ts, vec![1_000_000, 1_000_000]);
        assert_eq!(w.stats().window_emits, 1);
        assert!(out.is_empty(), "window emits rows, not records");
    }

    #[test]
    fn event_time_window_op_consumes_rows_and_tracks_watermark() {
        let mut w = EventTimeWindowOp::new(
            AggKind::Mean,
            16,
            2_000_000,
            1_000_000,
            0,
            500_000, // watermark bound
            0,
            LatePolicy::Drop,
        );
        let mut out = Vec::new();
        // Rows carry event timestamps; the third arrives out of order.
        let mut r = RowBatch::default();
        r.push(1, 10.0, 900_000, 1);
        r.push(1, 20.0, 950_000, 1);
        r.push(2, 7.0, 100_000, 1);
        w.apply(1_000_000, &mut r, &mut out).unwrap();
        assert!(r.is_empty(), "watermark 450ms is behind the first end (1s)");
        // Frontier 2.6s → watermark 2.1s → finalizes ends 1s and 2s.
        let mut r = RowBatch::default();
        r.push(3, 1.0, 2_600_000, 1);
        w.apply(2_700_000, &mut r, &mut out).unwrap();
        assert_eq!(r.ts, vec![1_000_000, 1_000_000, 2_000_000, 2_000_000]);
        assert_eq!(r.keys, vec![1, 2, 1, 2], "keys ascending per window");
        let s = w.stats();
        assert_eq!(s.window_emits, 2);
        assert_eq!(s.dropped_events, 0);
        assert!(
            s.watermark_lag_micros >= 600_000,
            "lag = now 2.7s − watermark 2.1s, got {}",
            s.watermark_lag_micros
        );
        assert!(out.is_empty(), "window emits rows, not records");
    }

    #[test]
    fn event_time_window_op_finish_flushes_open_panes() {
        let mut w = EventTimeWindowOp::new(
            AggKind::Sum,
            4,
            2_000_000,
            1_000_000,
            0,
            1_000_000,
            0,
            LatePolicy::MergeIfOpen,
        );
        let mut out = Vec::new();
        let mut r = RowBatch::default();
        r.push(2, 5.0, 400_000, 1);
        r.push(2, 7.0, 600_000, 1);
        w.apply(700_000, &mut r, &mut out).unwrap();
        assert!(r.is_empty());
        let mut r = RowBatch::default();
        w.finish(800_000, &mut r, &mut out).unwrap();
        assert_eq!(r.keys, vec![2]);
        assert_eq!(r.vals, vec![12.0]);
        assert_eq!(r.counts, vec![2]);
        assert_eq!(w.stats().events_in, 2);
    }

    #[test]
    fn event_time_drop_policy_counts_dropped_rows() {
        let mut w = EventTimeWindowOp::new(
            AggKind::Mean,
            4,
            1_000_000,
            1_000_000,
            0,
            0, // zero bound: watermark rides the frontier
            0,
            LatePolicy::Drop,
        );
        let mut out = Vec::new();
        let mut r = RowBatch::default();
        r.push(0, 1.0, 5_000_000, 1);
        w.apply(5_000_000, &mut r, &mut out).unwrap();
        // A record 5s behind the frontier: every covering window is gone.
        let mut r = RowBatch::default();
        r.push(0, 9.0, 100_000, 1);
        w.apply(5_100_000, &mut r, &mut out).unwrap();
        let s = w.stats();
        assert_eq!(s.dropped_events, 1);
        assert_eq!(s.events_in, 2);
    }

    #[test]
    fn topk_keeps_largest_per_window() {
        let mut t = TopKOp::new(2);
        let mut r = RowBatch::default();
        // Window A at ts 100: vals 5, 9, 1 → keep 9, 5.
        r.push(0, 5.0, 100, 1);
        r.push(1, 9.0, 100, 1);
        r.push(2, 1.0, 100, 1);
        // Window B at ts 200: vals 3, 3, 2 → tie on 3 keeps smaller keys.
        r.push(7, 3.0, 200, 1);
        r.push(4, 3.0, 200, 1);
        r.push(5, 2.0, 200, 1);
        let mut out = Vec::new();
        t.apply(0, &mut r, &mut out).unwrap();
        assert_eq!(r.ts, vec![100, 100, 200, 200]);
        assert_eq!(r.keys, vec![0, 1, 7, 4], "original emission order kept");
        assert_eq!(t.stats().events_out, 4);
    }

    #[test]
    fn emit_aggregates_serializes_window_rows() {
        let mut e = EmitAggregatesOp::new(AggKind::Mean);
        let mut r = RowBatch::default();
        r.push(3, 15.0, 2_000_000, 2);
        let mut out = Vec::new();
        e.apply(0, &mut r, &mut out).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(
            std::str::from_utf8(out[0].payload()).unwrap(),
            "{\"win\":2000000,\"id\":3,\"avg\":15.000,\"n\":2}"
        );
        assert_eq!(out[0].key, 3);
        assert_eq!(out[0].gen_ts_micros, 2_000_000);
        // Non-mean aggregators change the field name.
        let mut e = EmitAggregatesOp::new(AggKind::Max);
        let mut out = Vec::new();
        e.apply(0, &mut r, &mut out).unwrap();
        assert!(std::str::from_utf8(out[0].payload()).unwrap().contains("\"max\":"));
    }

    #[test]
    fn chained_filter_window_topk_runs_end_to_end() {
        let ops: Vec<Box<dyn Operator>> = vec![
            Box::new(FilterOp::new(CmpOp::Gt, 0.0)),
            Box::new(KeyByOp::new(4)),
            Box::new(WindowAggregateOp::new(
                OpCompute::Native,
                AggKind::Mean,
                8,
                1_000_000,
                500_000,
                0,
            )),
            Box::new(TopKOp::new(2)),
            Box::new(EmitAggregatesOp::new(AggKind::Mean)),
        ];
        let mut chain = Chain::from_ops("chain[test]", ops).unwrap();
        assert!(chain.needs_parse());

        let batch = EventBatch {
            ids: vec![0, 1, 2, 5, 6, 9],
            temps: vec![-1.0, 10.0, 20.0, 30.0, 40.0, 50.0],
            gen_ts: vec![0; 6],
            append_ts: vec![0; 6],
            payload_bytes: 6 * 27,
        };
        let mut out = Vec::new();
        chain.process(0, &[], &batch, &mut out).unwrap();
        assert!(out.is_empty(), "no window boundary yet");
        chain.process(500_000, &[], &EventBatch::default(), &mut out).unwrap();
        // Keys after filter(+keyby 4): 1, 2, 1(5), 2(6), 1(9) → means per
        // key: key1 = (10+30+50)/3 = 30, key2 = (20+40)/2 = 30 → top-2
        // keeps both.
        assert_eq!(out.len(), 2);
        let s = chain.stats();
        assert_eq!(s.events_in, 6, "chain intake is the first op's intake");
        assert_eq!(s.events_out, 2, "egestion records actually pushed");
        assert_eq!(s.window_emits, 1);
        let per_op = chain.operator_stats();
        assert_eq!(per_op.len(), 5);
        assert_eq!(per_op[0].0, "filter");
        assert_eq!(per_op[0].1.events_out, 5, "filter dropped the -1.0 row");
        assert_eq!(per_op[3].0, "topk");
    }

    #[test]
    fn chain_finish_flushes_through_downstream_ops() {
        let ops: Vec<Box<dyn Operator>> = vec![
            Box::new(WindowAggregateOp::new(
                OpCompute::Native,
                AggKind::Sum,
                4,
                2_000_000,
                1_000_000,
                0,
            )),
            Box::new(EmitAggregatesOp::new(AggKind::Sum)),
        ];
        let mut chain = Chain::from_ops("chain[flush]", ops).unwrap();
        let batch = EventBatch {
            ids: vec![3, 3],
            temps: vec![5.0, 7.0],
            gen_ts: vec![100, 100],
            append_ts: vec![100, 100],
            payload_bytes: 54,
        };
        let mut out = Vec::new();
        chain.process(100, &[], &batch, &mut out).unwrap();
        assert!(out.is_empty());
        chain.finish(200, &mut out).unwrap();
        assert_eq!(out.len(), 1, "finish must flush the pending pane downstream");
        assert!(std::str::from_utf8(out[0].payload()).unwrap().contains("\"sum\":12.000"));
    }

    #[test]
    fn raw_forward_chain_skips_parsing() {
        let mut chain =
            Chain::from_ops("passthrough", vec![Box::new(ForwardOp::default()) as Box<dyn Operator>])
                .unwrap();
        assert!(!chain.needs_parse());
        let records = vec![
            Record::new(1, vec![1u8, 2, 3], 10),
            Record::new(2, vec![4u8, 5], 20),
        ];
        let mut out = Vec::new();
        chain.process(0, &records, &EventBatch::default(), &mut out).unwrap();
        assert_eq!(out.len(), 2);
        assert!(out[0].shares_storage_with(&records[0]));
        let s = chain.stats();
        assert_eq!(s.events_in, 2);
        assert_eq!(s.events_out, 2);
        chain.finish(1, &mut out).unwrap();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn raw_forward_must_be_alone() {
        let ops: Vec<Box<dyn Operator>> = vec![
            Box::new(ForwardOp::default()),
            Box::new(MapOp::new(1.0, 0.0)),
        ];
        assert!(Chain::from_ops("bad", ops).is_err());
    }

    fn window_emit_chain() -> Chain {
        let ops: Vec<Box<dyn Operator>> = vec![
            Box::new(WindowAggregateOp::new(
                OpCompute::Native,
                AggKind::Mean,
                8,
                2_000_000,
                1_000_000,
                0,
            )),
            Box::new(EmitAggregatesOp::new(AggKind::Mean)),
        ];
        Chain::from_ops("chain[ckpt]", ops).unwrap()
    }

    #[test]
    fn chain_snapshot_restore_resumes_byte_identically() {
        let mut live = window_emit_chain();
        let batch = EventBatch {
            ids: vec![1, 1, 3],
            temps: vec![10.0, 20.0, 5.0],
            gen_ts: vec![100, 200, 300],
            append_ts: vec![100, 200, 300],
            payload_bytes: 81,
        };
        let mut out = Vec::new();
        live.process(300, &[], &batch, &mut out).unwrap();
        assert!(out.is_empty(), "open pane: nothing emitted yet");

        // Checkpoint mid-pane, restore into a freshly compiled chain.
        let state = PipelineStep::snapshot(&live).unwrap();
        let mut restored = window_emit_chain();
        PipelineStep::restore(&mut restored, &state).unwrap();

        // Both continue over the same input; egestion must match byte for
        // byte (the crash/restore equivalence contract in miniature).
        let tail = EventBatch {
            ids: vec![1, 3],
            temps: vec![30.0, 15.0],
            gen_ts: vec![400, 500],
            append_ts: vec![400, 500],
            payload_bytes: 54,
        };
        let mut out_live = Vec::new();
        let mut out_restored = Vec::new();
        live.process(1_000_000, &[], &tail, &mut out_live).unwrap();
        restored
            .process(1_000_000, &[], &tail, &mut out_restored)
            .unwrap();
        live.finish(2_000_000, &mut out_live).unwrap();
        restored.finish(2_000_000, &mut out_restored).unwrap();
        assert!(!out_live.is_empty(), "the flushed pane must emit");
        assert_eq!(out_live.len(), out_restored.len());
        for (a, b) in out_live.iter().zip(&out_restored) {
            assert_eq!(a.payload(), b.payload());
        }
    }

    #[test]
    fn chain_restore_rejects_mismatched_shape_readably() {
        let live = window_emit_chain();
        let state = live.snapshot_ops();
        // A chain with a different operator sequence must refuse the state.
        let mut other = Chain::from_ops(
            "chain[other]",
            vec![Box::new(MapOp::new(1.0, 0.0)) as Box<dyn Operator>],
        )
        .unwrap();
        let err = other.restore_ops(&state).unwrap_err();
        assert!(err.contains("different pipeline spec"), "{err}");
        // Same length, different op: name check catches it.
        let mut two = Chain::from_ops(
            "chain[two]",
            vec![
                Box::new(MapOp::new(1.0, 0.0)) as Box<dyn Operator>,
                Box::new(EmitEventsOp::new(27)) as Box<dyn Operator>,
            ],
        )
        .unwrap();
        let err = two.restore_ops(&state).unwrap_err();
        assert!(err.contains("does not match pipeline operator"), "{err}");
    }

    #[test]
    fn compile_missing_custom_registry_is_a_readable_error() {
        let cfg = BenchConfig::default();
        let spec = PipelineSpec {
            ops: vec![OpSpec::Custom {
                name: "mystery".into(),
                params: crate::util::json::Json::obj(),
            }],
        };
        let err = Chain::compile(&cfg, &spec, "x", None, None, 0).unwrap_err();
        assert!(err.contains("mystery"), "{err}");
        assert!(err.contains("with_registry"), "{err}");
    }
}
