"""L2/L1 structural tests on the lowered HLO.

These pin the Hardware-Adaptation claims of DESIGN.md §6: the keyed-window
scatter lowers to a dense dot (MXU mapping), the transform stays a fused
elementwise computation, state threads through without extra copies, and
block-size choices do not change numerics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.sensor_transform import sensor_transform


def lower_text(fn, *specs):
    lowered = jax.jit(fn).lower(*specs)
    return aot.to_hlo_text(lowered)


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


class TestHloStructure:
    def test_mem_step_lowers_to_dot(self):
        """The one-hot scatter must be a dense dot (MXU), not a scatter op."""
        text = lower_text(
            model.mem_pipeline_step,
            spec((1024,), jnp.int32),
            spec((1024,), jnp.float32),
            spec((1024,), jnp.float32),
            spec((1024,), jnp.float32),
        )
        assert "dot(" in text or "dot." in text, "masked-matmul lowering lost"
        assert "scatter" not in text.lower(), "fell back to scatter lowering"

    def test_cpu_step_has_no_dot(self):
        """The transform is purely elementwise — no contraction anywhere.
        (A `while` IS present: interpret-mode pallas_call lowers the grid
        as a loop; that is the expected HBM→VMEM schedule skeleton.)"""
        text = lower_text(
            lambda t, th: sensor_transform(t, th),
            spec((1024,), jnp.float32),
            spec((1,), jnp.float32),
        )
        assert "dot(" not in text
        assert "while" in text.lower(), "grid loop vanished — BlockSpec ignored?"

    def test_entry_parameter_counts(self):
        cpu = lower_text(
            lambda t, th: sensor_transform(t, th),
            spec((256,), jnp.float32),
            spec((1,), jnp.float32),
        )
        assert cpu.count("parameter(0)") >= 1 and cpu.count("parameter(1)") >= 1
        fused = lower_text(
            model.fused_pipeline_step,
            spec((256,), jnp.int32),
            spec((256,), jnp.float32),
            spec((1,), jnp.float32),
            spec((1024,), jnp.float32),
            spec((1024,), jnp.float32),
        )
        assert "parameter(4)" in fused, "fused step must take 5 inputs"

    def test_block_size_does_not_change_numerics(self):
        temps = jnp.asarray(np.random.default_rng(0).standard_normal(1024).astype(np.float32))
        th = jnp.array([10.0], dtype=jnp.float32)
        f128, a128 = sensor_transform(temps, th, block=128)
        f512, a512 = sensor_transform(temps, th, block=512)
        np.testing.assert_allclose(f128, f512, rtol=1e-6)
        np.testing.assert_array_equal(a128, a512)

    def test_fused_is_one_module_not_two(self):
        """Fusing must not duplicate the transform computation."""
        text = lower_text(
            model.fused_pipeline_step,
            spec((1024,), jnp.int32),
            spec((1024,), jnp.float32),
            spec((1,), jnp.float32),
            spec((1024,), jnp.float32),
            spec((1024,), jnp.float32),
        )
        # One entry computation, and the °F affine constant appears a
        # bounded number of times (no wholesale duplication).  Count the
        # actual HLO constant — the bare substring "1.8" also matches SSA
        # identifiers like `Arg_1.8`, which made this assertion flaky
        # across jaxlib versions.
        assert text.count("ENTRY") == 1
        assert text.count("constant(1.8)") >= 1, "transform constant missing"
        assert text.count("constant(1.8)") <= 4, "transform appears duplicated"


class TestAotManifestContract:
    """The Rust runtime trusts these properties of the manifest."""

    def test_every_variant_has_unique_file(self):
        files = [dict(v[3], name=v[0]) for v in ()]  # placate linters
        names = set()
        file_names = set()
        for name, _fn, _args, _meta in aot.variants():
            assert name not in names
            names.add(name)
            file_names.add(f"{name}.hlo.txt")
        assert len(file_names) == len(names)

    def test_batch_sizes_cover_block_constraints(self):
        # Every cpu batch size must be a multiple of its block choice.
        for b in aot.BATCH_SIZES:
            blk = min(512, b)
            assert b % blk == 0, f"batch {b} not divisible by block {blk}"

    def test_key_width_matches_rust_constant(self):
        # rust/src/pipelines/mod.rs: HLO_KEYS = 1024 must stay in sync.
        assert aot.KEY_SIZES == (1024,)


@pytest.mark.parametrize("b", aot.BATCH_SIZES)
def test_every_cpu_variant_is_lowerable(b):
    blk = min(512, b)
    text = lower_text(
        lambda t, th: sensor_transform(t, th, block=blk),
        spec((b,), jnp.float32),
        spec((1,), jnp.float32),
    )
    assert text.lstrip().startswith("HloModule")
