"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes, block sizes, key counts, value ranges, and id
distributions; every case asserts allclose against ``kernels.ref``.  These
tests gate artifact validity — if they fail, the HLO the Rust engine runs
is wrong.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.keyed_window import keyed_window_update
from compile.kernels.sensor_transform import sensor_transform

F32 = np.float32


def _temps(rng, b, scale=50.0):
    return jnp.asarray(rng.standard_normal(b).astype(F32) * scale)


# ---------------------------------------------------------------------------
# sensor_transform (CPU-intensive pipeline kernel)
# ---------------------------------------------------------------------------


class TestSensorTransform:
    @settings(max_examples=40, deadline=None)
    @given(
        blocks=st.integers(1, 16),
        block=st.sampled_from([128, 256, 512]),
        thresh=st.floats(-100, 200, allow_nan=False, width=32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, blocks, block, thresh, seed):
        rng = np.random.default_rng(seed)
        b = blocks * block
        temps = _temps(rng, b)
        th = jnp.array([thresh], dtype=jnp.float32)
        fahr, alerts = sensor_transform(temps, th, block=block)
        rfahr, ralerts = ref.sensor_transform_ref(temps, th)
        np.testing.assert_allclose(fahr, rfahr, rtol=1e-5, atol=1e-5)
        # Mask may legitimately differ where fahr is within float eps of the
        # threshold; exclude the knife-edge.
        edge = np.abs(np.asarray(rfahr) - thresh) < 1e-3
        np.testing.assert_array_equal(
            np.asarray(alerts)[~edge], np.asarray(ralerts)[~edge]
        )

    def test_known_values(self):
        # 0°C=32°F, 100°C=212°F, -40 is the fixed point.
        temps = jnp.array([0.0, 100.0, -40.0, 37.0] * 128, dtype=jnp.float32)
        th = jnp.array([100.0], dtype=jnp.float32)
        fahr, alerts = sensor_transform(temps, th)
        np.testing.assert_allclose(
            np.asarray(fahr)[:4], [32.0, 212.0, -40.0, 98.6], rtol=1e-6
        )
        np.testing.assert_array_equal(np.asarray(alerts)[:4], [0.0, 1.0, 0.0, 0.0])

    def test_alerts_are_binary(self):
        rng = np.random.default_rng(7)
        temps = _temps(rng, 1024)
        th = jnp.array([50.0], dtype=jnp.float32)
        _, alerts = sensor_transform(temps, th)
        assert set(np.unique(np.asarray(alerts))) <= {0.0, 1.0}

    def test_batch_equal_to_block(self):
        # Degenerate single-step grid (B == block) must still be exact.
        temps = jnp.linspace(-50, 50, 256, dtype=jnp.float32)
        th = jnp.array([0.0], dtype=jnp.float32)
        fahr, _ = sensor_transform(temps, th, block=256)
        rfahr, _ = ref.sensor_transform_ref(temps, th)
        np.testing.assert_allclose(fahr, rfahr, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# keyed_window_update (memory-intensive pipeline kernel)
# ---------------------------------------------------------------------------


class TestKeyedWindow:
    @settings(max_examples=25, deadline=None)
    @given(
        tiles=st.integers(1, 8),
        k=st.sampled_from([128, 512, 1024]),
        pad_frac=st.floats(0.0, 0.5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, tiles, k, pad_frac, seed):
        rng = np.random.default_rng(seed)
        b = tiles * 256
        ids = rng.integers(0, k, b).astype(np.int32)
        # Padded slots carry id == K (out of range) and must be dropped.
        ids[rng.random(b) < pad_frac] = k
        ids = jnp.asarray(ids)
        temps = _temps(rng, b)
        s0 = jnp.asarray(rng.standard_normal(k).astype(F32))
        c0 = jnp.asarray(rng.integers(0, 100, k).astype(F32))
        ns, nc, avg = keyed_window_update(ids, temps, s0, c0)
        rs, rc, ravg = ref.keyed_window_update_ref(ids, temps, s0, c0)
        np.testing.assert_allclose(ns, rs, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(nc, rc)
        np.testing.assert_allclose(avg, ravg, rtol=1e-4, atol=1e-4)

    def test_state_carry_across_batches(self):
        # Two sequential updates == one update over the concatenated batch.
        rng = np.random.default_rng(3)
        k = 256
        ids1 = jnp.asarray(rng.integers(0, k, 256).astype(np.int32))
        ids2 = jnp.asarray(rng.integers(0, k, 256).astype(np.int32))
        t1, t2 = _temps(rng, 256), _temps(rng, 256)
        z = jnp.zeros(k, jnp.float32)
        s1, c1, _ = keyed_window_update(ids1, t1, z, z)
        s2, c2, _ = keyed_window_update(ids2, t2, s1, c1)
        sall, call, _ = keyed_window_update(
            jnp.concatenate([ids1, ids2]), jnp.concatenate([t1, t2]), z, z
        )
        np.testing.assert_allclose(s2, sall, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(c2, call)

    def test_all_padding_is_noop(self):
        k = 128
        ids = jnp.full(256, k, dtype=jnp.int32)  # every slot out of range
        temps = jnp.ones(256, jnp.float32) * 99.0
        s0 = jnp.arange(k, dtype=jnp.float32)
        c0 = jnp.ones(k, jnp.float32)
        ns, nc, avg = keyed_window_update(ids, temps, s0, c0)
        np.testing.assert_allclose(ns, s0)
        np.testing.assert_allclose(nc, c0)
        np.testing.assert_allclose(avg, s0 / jnp.maximum(c0, 1.0))

    def test_single_hot_key(self):
        k = 128
        ids = jnp.zeros(512, dtype=jnp.int32)  # all events hit key 0
        temps = jnp.full(512, 2.0, jnp.float32)
        z = jnp.zeros(k, jnp.float32)
        ns, nc, avg = keyed_window_update(ids, temps, z, z)
        assert float(ns[0]) == pytest.approx(1024.0)
        assert float(nc[0]) == 512.0
        assert float(avg[0]) == pytest.approx(2.0)
        np.testing.assert_allclose(np.asarray(ns)[1:], 0.0)

    def test_zero_count_avg_is_zero_not_nan(self):
        k = 64
        ids = jnp.full(256, k, dtype=jnp.int32)
        temps = jnp.zeros(256, jnp.float32)
        z = jnp.zeros(k, jnp.float32)
        _, _, avg = keyed_window_update(ids, temps, z, z)
        assert not np.any(np.isnan(np.asarray(avg)))
