"""AOT pipeline tests: HLO text is emitted, well-formed, and the manifest
describes every artifact's I/O signature consistently."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Run the AOT pipeline once into a temp dir."""
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(out)],
        cwd=here,
        check=True,
        capture_output=True,
    )
    return out


def test_variants_cover_all_programs():
    names = [name for name, *_ in aot.variants()]
    assert any(n.startswith("cpu_") for n in names)
    assert any(n.startswith("mem_") for n in names)
    assert any(n.startswith("fused_") for n in names)
    # One variant per (program, batch[, keys]) — no duplicates.
    assert len(names) == len(set(names))


def test_manifest_matches_files(built):
    manifest = json.loads((built / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert len(manifest["source_sha256"]) == 64
    for entry in manifest["artifacts"]:
        p = built / entry["file"]
        assert p.exists(), entry["file"]
        text = p.read_text()
        # HLO text sanity: a module header and an ENTRY computation.
        assert text.lstrip().startswith("HloModule")
        assert "ENTRY" in text
        assert entry["batch"] in aot.BATCH_SIZES
        assert all("dtype" in io and "shape" in io for io in entry["inputs"])
        assert all("dtype" in io and "shape" in io for io in entry["outputs"])


def test_manifest_io_signatures(built):
    manifest = json.loads((built / "manifest.json").read_text())
    by_name = {e["name"]: e for e in manifest["artifacts"]}
    cpu = by_name["cpu_b1024"]
    assert [i["shape"] for i in cpu["inputs"]] == [[1024], [1]]
    assert [o["shape"] for o in cpu["outputs"]] == [[1024], [1024]]
    mem = by_name["mem_b1024_k1024"]
    assert [i["dtype"] for i in mem["inputs"]] == [
        "int32",
        "float32",
        "float32",
        "float32",
    ]
    assert [o["shape"] for o in mem["outputs"]] == [[1024], [1024], [1024]]
    fused = by_name["fused_b1024_k1024"]
    assert len(fused["inputs"]) == 5 and len(fused["outputs"]) == 5


def test_source_hash_is_stable():
    assert aot.source_hash() == aot.source_hash()


def test_hlo_text_has_no_64bit_id_issue(built):
    """The interchange gotcha: text (not proto) round-trips on xla 0.5.1.

    We can't run the Rust loader from pytest, but we can assert the text
    parses back through the local xla_client, which exercises the same
    parser family.
    """
    from jax._src.lib import xla_client as xc

    text = (built / "cpu_b1024.hlo.txt").read_text()
    # Round-trip through the HLO parser via XlaComputation.
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None
