"""L2 model tests: pipeline-step composition, shapes, and semantics."""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


class TestCpuPipelineStep:
    def test_shapes(self):
        temps = jnp.zeros(1024, jnp.float32)
        th = jnp.array([80.0], dtype=jnp.float32)
        fahr, alerts = model.cpu_pipeline_step(temps, th)
        assert fahr.shape == (1024,) and alerts.shape == (1024,)
        assert fahr.dtype == jnp.float32 and alerts.dtype == jnp.float32


class TestMemPipelineStep:
    def test_shapes_and_state(self):
        rng = np.random.default_rng(0)
        ids = jnp.asarray(rng.integers(0, 1024, 1024).astype(np.int32))
        temps = jnp.asarray(rng.standard_normal(1024).astype(np.float32))
        z = jnp.zeros(1024, jnp.float32)
        ns, nc, avg = model.mem_pipeline_step(ids, temps, z, z)
        assert ns.shape == nc.shape == avg.shape == (1024,)
        assert float(jnp.sum(nc)) == 1024.0  # every event landed on a key


class TestFusedPipelineStep:
    def test_window_aggregates_fahrenheit(self):
        """The fused step's window state must accumulate °F, not °C."""
        rng = np.random.default_rng(1)
        b, k = 512, 128
        ids = jnp.asarray(rng.integers(0, k, b).astype(np.int32))
        temps = jnp.asarray(rng.standard_normal(b).astype(np.float32) * 30)
        th = jnp.array([80.0], dtype=jnp.float32)
        z = jnp.zeros(k, jnp.float32)
        fahr, alerts, ns, nc, avg = model.fused_pipeline_step(ids, temps, th, z, z)
        rfahr, ralerts = ref.sensor_transform_ref(temps, th)
        rs, rc, ravg = ref.keyed_window_update_ref(ids, rfahr, z, z)
        np.testing.assert_allclose(fahr, rfahr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(ns, rs, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(nc, rc)
        np.testing.assert_allclose(avg, ravg, rtol=1e-4, atol=1e-4)

    def test_consistent_with_unfused(self):
        rng = np.random.default_rng(2)
        b, k = 256, 128
        ids = jnp.asarray(rng.integers(0, k, b).astype(np.int32))
        temps = jnp.asarray(rng.standard_normal(b).astype(np.float32) * 30)
        th = jnp.array([70.0], dtype=jnp.float32)
        z = jnp.zeros(k, jnp.float32)
        fahr_u, alerts_u = model.cpu_pipeline_step(temps, th)
        ns_u, nc_u, avg_u = model.mem_pipeline_step(ids, fahr_u, z, z)
        fahr_f, alerts_f, ns_f, nc_f, avg_f = model.fused_pipeline_step(
            ids, temps, th, z, z
        )
        np.testing.assert_allclose(fahr_f, fahr_u, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(alerts_f, alerts_u)
        np.testing.assert_allclose(ns_f, ns_u, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(avg_f, avg_u, rtol=1e-4, atol=1e-4)
