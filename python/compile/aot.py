"""AOT compiler: lower every L2 program variant to HLO text + manifest.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust ``xla``
crate's bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <=
INT_MAX``).  The HLO text parser reassigns ids, so text round-trips
cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --outdir ../artifacts

Outputs one ``<name>.hlo.txt`` per (program × batch size) variant plus a
``manifest.json`` describing each artifact's I/O signature — the Rust
runtime (``rust/src/runtime``) keys its executable cache off this manifest
and `make artifacts` uses its source hash for staleness.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Batch-size variants the Rust batcher can pick from.  Must be multiples of
# the kernels' block sizes (sensor_transform BLOCK=512 divides 1024/4096 but
# not 256 — the kernel's pallas_call grid requires block | B, so 256 uses the
# elementwise kernel with block=256 via static arg).
BATCH_SIZES = (256, 1024, 4096)
# Keyed-state width (number of distinct sensor ids the window tracks).
KEY_SIZES = (1024,)
DEFAULT_THRESH_SHAPE = (1,)


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO module → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _io_sig(args, lowered):
    """Manifest I/O signature: dtypes + shapes for inputs and outputs."""
    ins = [{"dtype": str(a.dtype), "shape": list(a.shape)} for a in args]
    out_avals = lowered.out_info
    outs = [
        {"dtype": str(o.dtype), "shape": list(o.shape)}
        for o in jax.tree_util.tree_leaves(out_avals)
    ]
    return ins, outs


def variants():
    """Yield (name, fn, example_args, meta) for every artifact to build."""
    for b in BATCH_SIZES:
        # sensor_transform's default BLOCK=512 must divide B; for B=256 pass
        # block=256 through a wrapper so the grid stays exact.
        blk = min(512, b)

        def cpu_fn(temps, thresh, _blk=blk):
            from compile.kernels.sensor_transform import sensor_transform

            return sensor_transform(temps, thresh, block=_blk)

        yield (
            f"cpu_b{b}",
            cpu_fn,
            (_spec((b,), jnp.float32), _spec(DEFAULT_THRESH_SHAPE, jnp.float32)),
            {"program": "cpu_pipeline_step", "batch": b, "keys": 0},
        )
    for b in BATCH_SIZES:
        for k in KEY_SIZES:
            yield (
                f"mem_b{b}_k{k}",
                model.mem_pipeline_step,
                (
                    _spec((b,), jnp.int32),
                    _spec((b,), jnp.float32),
                    _spec((k,), jnp.float32),
                    _spec((k,), jnp.float32),
                ),
                {"program": "mem_pipeline_step", "batch": b, "keys": k},
            )
    for b in BATCH_SIZES:
        for k in KEY_SIZES:
            blk = min(512, b)

            def fused_fn(ids, temps, thresh, s, c, _blk=blk):
                from compile.kernels.keyed_window import keyed_window_update
                from compile.kernels.sensor_transform import sensor_transform

                fahr, alerts = sensor_transform(temps, thresh, block=_blk)
                ns, nc, avg = keyed_window_update(ids, fahr, s, c)
                return fahr, alerts, ns, nc, avg

            yield (
                f"fused_b{b}_k{k}",
                fused_fn,
                (
                    _spec((b,), jnp.int32),
                    _spec((b,), jnp.float32),
                    _spec(DEFAULT_THRESH_SHAPE, jnp.float32),
                    _spec((k,), jnp.float32),
                    _spec((k,), jnp.float32),
                ),
                {"program": "fused_pipeline_step", "batch": b, "keys": k},
            )


def source_hash() -> str:
    """sha256 over the compile-path sources, for `make artifacts` staleness."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    entries = []
    for name, fn, example_args, meta in variants():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, fname), "w") as f:
            f.write(text)
        ins, outs = _io_sig(example_args, lowered)
        entries.append(
            {
                "name": name,
                "file": fname,
                **meta,
                "inputs": ins,
                "outputs": outs,
            }
        )
        print(f"  lowered {name:18s} -> {fname} ({len(text)} chars)")

    manifest = {
        "version": 1,
        "source_sha256": source_hash(),
        "artifacts": entries,
    }
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.outdir}")


if __name__ == "__main__":
    main()
