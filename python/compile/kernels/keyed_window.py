"""L1 Pallas kernel: the memory-intensive pipeline's keyed window update.

The paper's memory-intensive pipeline (Sec. 3.3) keys the sensor stream by
sensor ID and maintains a sliding-window mean temperature per key as
operator state.  The Rust engine batches events and carries ``(sum, cnt)``
state tensors across batches (one pane of the sliding window; pane merging
is L3's job).  This kernel performs one batch's state update:

    sum'[k] = sum[k] + Σ_b  temps[b] · [ids[b] == k]
    cnt'[k] = cnt[k] + Σ_b  [ids[b] == k]
    avg [k] = sum'[k] / max(cnt'[k], 1)

TPU mapping (DESIGN.md §6): the scatter-add is re-expressed as a masked
matmul — ``one_hot(ids)ᵀ @ temps`` — which runs on the MXU for the K sizes
the benchmark uses (K ≤ 4096 sensors).  The kernel tiles over the batch
dimension; the ``f32[K]`` accumulators stay VMEM-resident across all grid
steps (the Pallas accumulator pattern), mirroring Flink keeping keyed state
in managed memory.  Grid iterates sequentially on TPU, so accumulating into
the output ref across steps is well-defined.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile per grid step.  Each step materialises a (BLOCK_B, K) one-hot
# mask in VMEM: 256×1024 f32 = 1 MiB — comfortably VMEM-resident alongside
# the K-sized accumulators, and a (256,K)×(256,) reduction feeds the MXU
# with full 128-lane tiles when K is a multiple of 128.
BLOCK_B = 256


def _window_kernel(ids_ref, temp_ref, sum_ref, cnt_ref, osum_ref, ocnt_ref):
    """One grid step: accumulate a batch tile into the keyed state."""
    step = pl.program_id(0)

    # Initialise the VMEM accumulators from the carried-in state on the
    # first step only; later steps accumulate in place.
    @pl.when(step == 0)
    def _init():
        osum_ref[...] = sum_ref[...]
        ocnt_ref[...] = cnt_ref[...]

    ids = ids_ref[...]
    temps = temp_ref[...]
    k = osum_ref.shape[0]
    # Masked-matmul scatter: mask[b, k] = (ids[b] == k).  dot(mask^T-style
    # reduction) maps onto the MXU; interpret mode computes it with numpy.
    keys = jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], k), 1)
    mask = (ids[:, None] == keys).astype(jnp.float32)
    osum_ref[...] += jnp.dot(temps, mask)
    ocnt_ref[...] += jnp.sum(mask, axis=0)


@functools.partial(jax.jit, static_argnames=("block_b",))
def keyed_window_update(ids, temps, state_sum, state_cnt, block_b=BLOCK_B):
    """One batched update of the keyed sliding-window pane state.

    Args:
      ids:       i32[B] sensor ids in ``[0, K)``.  Padded slots must carry
                 an id >= K so they fall outside every one-hot column.
      temps:     f32[B] temperatures (padded slots: value irrelevant).
      state_sum: f32[K] carried pane sums.
      state_cnt: f32[K] carried pane counts.

    Returns:
      (sum' f32[K], cnt' f32[K], avg f32[K]).
    """
    (b,) = ids.shape
    (k,) = state_sum.shape
    grid = (b // block_b,)
    new_sum, new_cnt = pl.pallas_call(
        _window_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        # Accumulators: every grid step maps to the same (whole-array) block,
        # so they live in VMEM across the sequential grid.
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(ids, temps, state_sum, state_cnt)
    avg = new_sum / jnp.maximum(new_cnt, 1.0)
    return new_sum, new_cnt, avg
