"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal: every kernel must match its oracle
to float32 tolerance across the hypothesis sweep in
``python/tests/test_kernels.py`` before an artifact is considered valid.
"""

import jax.numpy as jnp


def sensor_transform_ref(temps, thresh):
    """Oracle for kernels.sensor_transform: °C→°F + threshold mask."""
    fahr = temps * (9.0 / 5.0) + 32.0
    alerts = (fahr > thresh[0]).astype(jnp.float32)
    return fahr, alerts


def keyed_window_update_ref(ids, temps, state_sum, state_cnt):
    """Oracle for kernels.keyed_window_update: segment-sum state update.

    Padded slots carry ids >= K and must not contribute — jnp ``.at[].add``
    with out-of-bounds indices drops them (mode='drop'), matching the
    kernel's one-hot mask which has no column for id >= K.
    """
    new_sum = state_sum.at[ids].add(temps, mode="drop")
    new_cnt = state_cnt.at[ids].add(1.0, mode="drop")
    avg = new_sum / jnp.maximum(new_cnt, 1.0)
    return new_sum, new_cnt, avg
