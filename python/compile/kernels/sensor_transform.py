"""L1 Pallas kernel: the CPU-intensive pipeline's per-event transform.

The paper's CPU-intensive pipeline (Sec. 3.3) parses each sensor event,
converts the Celsius temperature to Fahrenheit, and checks it against an
alert threshold.  On the Rust side events are batched into ``f32[B]``
temperature tensors; this kernel is the batched tensor re-expression of
that per-event scalar loop (see DESIGN.md §6 Hardware-Adaptation).

TPU mapping: a pure VPU elementwise kernel.  Each grid step streams one
``(BLK,)`` block HBM→VMEM, applies the affine conversion plus compare, and
writes two output blocks.  The op is bandwidth-bound: the BlockSpec is
chosen so two blocks (in + out) stay far below VMEM while leaving room for
double buffering.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same program runs
on the Rust PJRT CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size for the elementwise grid.  512 f32 = 2 KiB per block; with
# in/out/alert blocks live simultaneously this is ~6 KiB of VMEM per grid
# step — far under the ~16 MiB VMEM budget, leaving the compiler free to
# double-buffer the HBM→VMEM stream.
BLOCK = 512


def _transform_kernel(temp_ref, thresh_ref, fahr_ref, alert_ref):
    """One grid step: convert a block of temperatures, emit alert mask."""
    t = temp_ref[...]
    f = t * (9.0 / 5.0) + 32.0
    fahr_ref[...] = f
    # Alert mask as f32 (0.0 / 1.0) so the whole artifact stays single-dtype
    # on the output side; the Rust engine thresholds on > 0.5.
    alert_ref[...] = jnp.where(f > thresh_ref[...], 1.0, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def sensor_transform(temps, thresh, block=BLOCK):
    """Batched CPU-pipeline transform.

    Args:
      temps:  f32[B]  Celsius temperatures (B must be a multiple of `block`;
              the Rust batcher pads partial batches).
      thresh: f32[1]  alert threshold in Fahrenheit.
      block:  grid block size.

    Returns:
      (fahr f32[B], alerts f32[B]) — converted temperatures and 0/1 mask.
    """
    (b,) = temps.shape
    grid = (b // block,)
    return pl.pallas_call(
        _transform_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            # Threshold is broadcast: every grid step sees the same block.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(temps, thresh)
