"""L2: the paper's processing-pipeline compute graphs, written in JAX.

Each function is the batched tensor program for one SProBench pipeline
(Sec. 3.3 of the paper); each calls the L1 Pallas kernels and is AOT-lowered
by ``aot.py`` to HLO text, which the Rust engine executes via PJRT on its
hot path.  Python never runs at request time.

Programs
--------
* ``cpu_pipeline_step``   — CPU-intensive pipeline: °C→°F + threshold.
* ``mem_pipeline_step``   — memory-intensive pipeline: keyed window pane
                            update (sum/cnt state carried by the caller).
* ``fused_pipeline_step`` — both in one program: the transform feeds the
                            window (ablation: one PJRT dispatch instead of
                            two when a custom pipeline wants both).

All programs take/return flat tuples of f32/i32 tensors so Rust-side
marshalling stays trivial.
"""

from compile.kernels.keyed_window import keyed_window_update
from compile.kernels.sensor_transform import sensor_transform


def cpu_pipeline_step(temps, thresh):
    """CPU-intensive pipeline body.

    Args:
      temps:  f32[B] Celsius temperatures for one engine batch.
      thresh: f32[1] alert threshold (°F).

    Returns:
      (fahr f32[B], alerts f32[B]).
    """
    fahr, alerts = sensor_transform(temps, thresh)
    return fahr, alerts


def mem_pipeline_step(ids, temps, state_sum, state_cnt):
    """Memory-intensive pipeline body: one window-pane state update.

    Args:
      ids:       i32[B] sensor ids; padded slots carry id >= K.
      temps:     f32[B] Celsius temperatures.
      state_sum: f32[K] pane sums (carried across batches by the engine).
      state_cnt: f32[K] pane counts.

    Returns:
      (sum' f32[K], cnt' f32[K], avg f32[K]).
    """
    return keyed_window_update(ids, temps, state_sum, state_cnt)


def fused_pipeline_step(ids, temps, thresh, state_sum, state_cnt):
    """CPU + memory pipelines fused into a single dispatch.

    The window aggregates the *Fahrenheit* stream so the transform's output
    feeds the stateful stage (one HLO module, XLA fuses the elementwise
    stage into the scatter's operand producer).

    Returns:
      (fahr f32[B], alerts f32[B], sum' f32[K], cnt' f32[K], avg f32[K]).
    """
    fahr, alerts = sensor_transform(temps, thresh)
    new_sum, new_cnt, avg = keyed_window_update(ids, fahr, state_sum, state_cnt)
    return fahr, alerts, new_sum, new_cnt, avg
