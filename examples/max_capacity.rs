//! Max-capacity sweep: escalate the offered load on the pass-through
//! pipeline until this machine stops keeping up, then binary-search the
//! knee and report the maximum sustainable throughput (MST).
//!
//! Writes `runs/max-capacity-example/report.json` + `report.md` — the
//! same artifacts `sprobench max-capacity --config <yaml>` produces.
//!
//! ```bash
//! cargo run --release --example max_capacity
//! ```

use sprobench::bench::scenarios;
use sprobench::config::{BenchConfig, PipelineKind};
use sprobench::coordinator::run_wall;
use sprobench::experiment::MaxCapacityDriver;
use sprobench::runtime::RuntimeFactory;
use sprobench::util::units::fmt_count;

fn main() {
    // Wall-mode pass-through sweep: 1-second probes, doubling from 200K
    // ev/s, then 3 refinement rounds around the knee.
    let mut cfg = scenarios::max_capacity(PipelineKind::PassThrough);
    cfg.bench.name = "max-capacity-example".into();
    let rtf = RuntimeFactory::default_dir();
    cfg.engine.use_hlo = rtf.available();
    if !cfg.engine.use_hlo {
        eprintln!("artifacts/ not built — falling back to native compute (run `make artifacts`)");
    }
    let use_hlo = cfg.engine.use_hlo;

    let mut probes = 0u32;
    let mut driver = MaxCapacityDriver::new(cfg, |c: &BenchConfig| {
        probes += 1;
        eprintln!("probe at {} ev/s ...", fmt_count(c.workload.rate as f64));
        run_wall(c, use_hlo.then(|| rtf.clone()))
    });
    let report = driver.run().expect("sweep failed");
    drop(driver);

    let dir = std::path::Path::new("runs").join("max-capacity-example");
    std::fs::create_dir_all(&dir).expect("create report dir");
    std::fs::write(dir.join("report.json"), report.to_json().to_pretty())
        .expect("write report.json");
    std::fs::write(dir.join("report.md"), report.to_markdown()).expect("write report.md");

    println!("{}", report.to_markdown());
    println!(
        "{} probes; reports under {}",
        probes,
        dir.display()
    );
    assert!(report.iterations.len() >= 2, "escalation must probe repeatedly");
    println!("max_capacity OK");
}
