//! SLURM batch workflow demo: automatic resource calculation, sbatch
//! script generation — including the **multi-node distributed launch**
//! (one srun step per worker role over the TCP transport) — and a
//! simulated schedule of concurrent experiments with dependencies: the
//! paper's Sec. 3.1 workflow on the Barnard-scale cluster model.
//!
//! ```bash
//! cargo run --release --example slurm_batch
//! ```

use sprobench::config::{expand_experiments, yaml};
use sprobench::postprocess::ascii_table;
use sprobench::slurm::{resource_request, sbatch_script, ClusterSpec, Scheduler};
use sprobench::util::units::fmt_micros;
use sprobench::workflow::WorkflowManager;

const CONFIG: &str = "
benchmark:
  name: barnard-campaign
  duration: 10m
workload:
  rate: 8M
generators:
  max_instances: 64
broker:
  io_threads: 20
  network_threads: 10
slurm:
  enabled: true
  cpus_per_task: 26
  mem: 200GB
experiments:
  - name: w1M
    workload.rate: 1M
  - name: w2M
    workload.rate: 2M
  - name: w4M
    workload.rate: 4M
  - name: w8M
    workload.rate: 8M
";

/// A multi-node distributed campaign: broker, engine, and two generator
/// workers are separately scheduled srun steps that dial the driver over
/// TCP (`spawn_workers: false` — SLURM launches the processes, not the
/// driver; workers retry the control dial until the driver binds).
const DISTRIBUTED: &str = "
benchmark:
  name: barnard-distributed
  duration: 10m
workload:
  rate: 4M
slurm:
  enabled: true
  nodes: 5
  cpus_per_task: 26
cluster:
  transport: tcp
  spawn_workers: false
  driver_bind: 0.0.0.0:7700
  data_bind: 0.0.0.0:7701
  generators: 2
";

fn main() {
    let doc = yaml::parse(CONFIG).expect("config");
    let exps = expand_experiments(&doc).expect("expand");

    // 1. Automatic resource calculation per experiment.
    let rows: Vec<Vec<String>> = exps
        .iter()
        .map(|e| {
            let r = resource_request(&e.config);
            vec![
                e.name.clone(),
                r.nodes.to_string(),
                r.cpus_per_task.to_string(),
                format!("{} GB", r.mem_per_node_bytes >> 30),
                fmt_micros(r.time_limit_micros),
            ]
        })
        .collect();
    println!("automatic resource calculation (from the single master config):");
    println!(
        "{}",
        ascii_table(&["experiment", "nodes", "cpus/task", "mem/node", "time limit"], &rows)
    );

    // 2. One generated single-step sbatch script.
    println!("generated sbatch script for '{}':\n", exps[0].name);
    println!("{}", sbatch_script(&exps[0].config, "campaign.yaml"));

    // 3. The distributed variant: one srun step per worker role.
    let dist = expand_experiments(&yaml::parse(DISTRIBUTED).expect("distributed config"))
        .expect("expand distributed")
        .remove(0);
    let script = sbatch_script(&dist.config, "distributed.yaml");
    assert!(script.contains("--role broker"), "broker step missing");
    assert!(script.contains("--role engine"), "engine step missing");
    assert_eq!(script.matches("--role generator").count(), 2);
    println!(
        "generated multi-node distributed sbatch script for '{}':\n",
        dist.name
    );
    println!("{script}");

    // 4. Simulated schedule: concurrent submission on Barnard.
    let mut sched = Scheduler::new(ClusterSpec::default());
    let wm = WorkflowManager::new("runs");
    let ids = wm.submit_batch(&exps, &mut sched, false, |e| {
        e.config.bench.duration_micros + e.config.bench.warmup_micros
    });
    let makespan = sched.run_to_completion();
    let rows: Vec<Vec<String>> = ids
        .iter()
        .map(|&id| {
            let j = sched.job(id).expect("job");
            vec![
                j.request.name.clone(),
                format!("{:?}", j.state),
                fmt_micros(j.wait_micros().unwrap_or(0)),
                j.allocated_nodes.len().to_string(),
            ]
        })
        .collect();
    println!("simulated concurrent schedule (makespan {}):", fmt_micros(makespan));
    println!("{}", ascii_table(&["job", "state", "wait", "nodes"], &rows));
    let st = sched.stats();
    println!(
        "scheduler: {} completed, {} backfilled, utilization {:.1}%",
        st.completed,
        st.backfilled,
        st.utilization * 100.0
    );
}
