//! Cluster-scale simulation: the paper's headline numbers on the Barnard
//! model in virtual time.
//!
//! Reproduces (sim mode, calibrated model — DESIGN.md §1):
//!   * Table 1's 40 M events/s aggregate generator throughput,
//!   * the ≈0.5 GB/s single-node generation claim,
//!   * Fig. 7's paper-scale parallelism grid (0.5–8 M ev/s).
//!
//! ```bash
//! cargo run --release --example cluster_scale
//! ```

use sprobench::bench::scenarios;
use sprobench::config::PipelineKind;
use sprobench::coordinator::simrun::{run_sim, SimModel};
use sprobench::metrics::MeasurementPoint;
use sprobench::postprocess::ascii_table;
use sprobench::util::units::{fmt_count, fmt_micros, fmt_rate_bytes};

fn main() {
    let model = SimModel::default();

    // --- Headline: 40M ev/s aggregate across a 16-node allocation --------
    let mut cfg = scenarios::fig7_sim(64, 45_000_000);
    cfg.bench.name = "cluster-headline".into();
    cfg.engine.pipeline = PipelineKind::PassThrough;
    cfg.broker.partitions = 32;
    cfg.slurm.nodes = 16;
    let (headline, _) = run_sim(&cfg, &model);
    println!(
        "headline: offered {} ev/s, processed {} ev/s across {} generator instances",
        fmt_count(headline.offered_rate),
        fmt_count(headline.processed_rate),
        cfg.generator_instances(),
    );
    assert!(headline.offered_rate >= 40e6, "40M ev/s headline not reached");

    // --- Single node: 0.5 GB/s generation --------------------------------
    let mut node = scenarios::fig7_sim(16, 20_000_000);
    node.bench.name = "single-node".into();
    node.engine.pipeline = PipelineKind::PassThrough;
    node.broker.partitions = 16;
    node.slurm.nodes = 1;
    let (single, _) = run_sim(&node, &model);
    println!(
        "single node: {} at 27 B/event ({} ev/s)",
        fmt_rate_bytes(single.offered_bytes_rate),
        fmt_count(single.offered_rate),
    );
    assert!(
        single.offered_bytes_rate >= 0.5e9,
        "0.5 GB/s single-node claim not reached"
    );

    // --- Paper-scale Fig. 7 grid ------------------------------------------
    let mut rows = Vec::new();
    for &p in &scenarios::PARALLELISM_GRID {
        for &rate in &scenarios::PAPER_RATE_GRID {
            let (s, _) = run_sim(&scenarios::fig7_sim(p, rate), &model);
            let e2e = s.latency_at(MeasurementPoint::EndToEnd).expect("e2e");
            rows.push(vec![
                p.to_string(),
                fmt_count(rate as f64),
                format!("{} ev/s", fmt_count(s.processed_rate)),
                fmt_micros(e2e.p50),
                s.gc_young_count.to_string(),
                format!("{:.0} J", s.energy_joules),
            ]);
        }
    }
    println!(
        "\npaper-scale Fig. 7 grid (sim):\n{}",
        ascii_table(
            &["P", "offered", "processed", "e2e p50", "GC young", "energy"],
            &rows
        )
    );
    println!("cluster_scale OK");
}
