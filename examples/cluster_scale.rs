//! Cluster-scale sweeps: a **real multi-process loopback scaling sweep**
//! over the TCP transport (driver + broker + engine + generator worker
//! processes on 127.0.0.1), then the paper's headline numbers on the
//! calibrated Barnard model in virtual time.
//!
//! Reproduces:
//!   * a keyed-shuffle pipeline crossing a real wire at parallelism 2/4,
//!     with the `transport` wire counters from the merged results.json,
//!   * Table 1's 40 M events/s aggregate generator throughput (sim),
//!   * the ≈0.5 GB/s single-node generation claim (sim),
//!   * Fig. 7's paper-scale parallelism grid (sim, 0.5–8 M ev/s).
//!
//! ```bash
//! cargo run --release --example cluster_scale
//! ```
//!
//! The driver spawns its workers by re-executing this binary with
//! `worker --role …` arguments (the same protocol `sprobench worker`
//! speaks), so the whole sweep is self-contained.

use sprobench::bench::scenarios;
use sprobench::config::{expand_experiments, yaml, PipelineKind};
use sprobench::coordinator::simrun::{run_sim, SimModel};
use sprobench::metrics::MeasurementPoint;
use sprobench::net::runner::{run_driver, run_worker};
use sprobench::postprocess::ascii_table;
use sprobench::util::json::Json;
use sprobench::util::units::{fmt_count, fmt_micros, fmt_rate_bytes};

/// One loopback sweep point: engine parallelism × dedicated generator
/// worker processes (0 = fleet colocated with the broker worker).
const LOOPBACK_GRID: &[(u32, u32)] = &[(2, 0), (4, 1)];

fn loopback_yaml(parallelism: u32, generators: u32) -> String {
    format!(
        "benchmark:
  name: loopback-p{parallelism}-g{generators}
  mode: wall
  duration: 30s
  warmup: 0s
workload:
  rate: 200K
  events: 100000
  sensors: 64
engine:
  parallelism: {parallelism}
  use_hlo: false
  pipeline:
    ops:
      - keyby:
          modulo: 16
      - window:
          agg: mean
          window: 1s
          slide: 500ms
          time: event
          allowed_lateness: 20s
          late_policy: merge_if_open
          watermark: 500ms
      - emit: aggregates
cluster:
  transport: tcp
  generators: {generators}
"
    )
}

/// Re-entry path for the worker processes the driver spawns: this
/// example binary accepts the same `worker --role … --driver …` argv the
/// `sprobench` binary does.
fn worker_main(args: &[String]) -> ! {
    let get = |k: &str| {
        args.iter()
            .position(|a| a == k)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    let role = get("--role").expect("worker re-entry: --role missing");
    let driver = get("--driver").expect("worker re-entry: --driver missing");
    match run_worker(&role, &driver, get("--bind").as_deref()) {
        Ok(()) => std::process::exit(0),
        Err(e) => {
            eprintln!("worker error: {e}");
            std::process::exit(1);
        }
    }
}

fn int(results: &Json, path: &[&str]) -> i64 {
    results.path(path).and_then(|v| v.as_i64()).unwrap_or(0)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) == Some("worker") {
        worker_main(&args);
    }

    // --- Real multi-process loopback sweep (TCP transport) ---------------
    let mut rows = Vec::new();
    for &(parallelism, generators) in LOOPBACK_GRID {
        let doc = yaml::parse(&loopback_yaml(parallelism, generators)).expect("loopback yaml");
        let exp = expand_experiments(&doc).expect("expand").remove(0);
        let results = run_driver(&exp.config, &exp.resolved).expect("distributed run");
        let generated = int(&results, &["events", "generated"]);
        let processed = int(&results, &["events", "processed"]);
        assert_eq!(processed, generated, "conservation across the wire");
        assert!(
            int(&results, &["transport", "records"]) >= generated,
            "every record must cross the wire"
        );
        rows.push(vec![
            parallelism.to_string(),
            (3 + generators).to_string(), // broker + engine + driver(+gens)
            fmt_count(processed as f64),
            format!(
                "{} ev/s",
                fmt_count(
                    results
                        .path(&["throughput", "processed"])
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0)
                )
            ),
            format!(
                "{} rec / {:.1} MiB / {} frames",
                int(&results, &["transport", "records"]),
                int(&results, &["transport", "bytes"]) as f64 / (1024.0 * 1024.0),
                int(&results, &["transport", "frames"]),
            ),
        ]);
    }
    println!(
        "loopback multi-process sweep (keyed shuffle over TCP, count-bound):\n{}",
        ascii_table(&["P", "procs", "events", "processed", "wire"], &rows)
    );

    let model = SimModel::default();

    // --- Headline: 40M ev/s aggregate across a 16-node allocation --------
    let mut cfg = scenarios::fig7_sim(64, 45_000_000);
    cfg.bench.name = "cluster-headline".into();
    cfg.engine.pipeline = PipelineKind::PassThrough;
    cfg.broker.partitions = 32;
    cfg.slurm.nodes = 16;
    let (headline, _) = run_sim(&cfg, &model);
    println!(
        "headline: offered {} ev/s, processed {} ev/s across {} generator instances",
        fmt_count(headline.offered_rate),
        fmt_count(headline.processed_rate),
        cfg.generator_instances(),
    );
    assert!(headline.offered_rate >= 40e6, "40M ev/s headline not reached");

    // --- Single node: 0.5 GB/s generation --------------------------------
    let mut node = scenarios::fig7_sim(16, 20_000_000);
    node.bench.name = "single-node".into();
    node.engine.pipeline = PipelineKind::PassThrough;
    node.broker.partitions = 16;
    node.slurm.nodes = 1;
    let (single, _) = run_sim(&node, &model);
    println!(
        "single node: {} at 27 B/event ({} ev/s)",
        fmt_rate_bytes(single.offered_bytes_rate),
        fmt_count(single.offered_rate),
    );
    assert!(
        single.offered_bytes_rate >= 0.5e9,
        "0.5 GB/s single-node claim not reached"
    );

    // --- Paper-scale Fig. 7 grid ------------------------------------------
    let mut rows = Vec::new();
    for &p in &scenarios::PARALLELISM_GRID {
        for &rate in &scenarios::PAPER_RATE_GRID {
            let (s, _) = run_sim(&scenarios::fig7_sim(p, rate), &model);
            let e2e = s.latency_at(MeasurementPoint::EndToEnd).expect("e2e");
            rows.push(vec![
                p.to_string(),
                fmt_count(rate as f64),
                format!("{} ev/s", fmt_count(s.processed_rate)),
                fmt_micros(e2e.p50),
                s.gc_young_count.to_string(),
                format!("{:.0} J", s.energy_joules),
            ]);
        }
    }
    println!(
        "\npaper-scale Fig. 7 grid (sim):\n{}",
        ascii_table(
            &["P", "offered", "processed", "e2e p50", "GC young", "energy"],
            &rows
        )
    );
    println!("cluster_scale OK");
}
