//! END-TO-END DRIVER: the full SProBench stack on a real workload.
//!
//! Exercises every layer in one run (recorded in EXPERIMENTS.md §E2E):
//!
//! 1. One master config expands into a three-experiment matrix — the
//!    paper's three pipelines (pass-through / CPU-intensive /
//!    memory-intensive) on the same workload;
//! 2. the workflow manager gives each a run directory with the resolved
//!    config, generated sbatch script, metric exports and trace log;
//! 3. each experiment runs wall-mode: generator fleet → broker (4
//!    partitions) → engine (4 task slots, Flink personality, compute via
//!    the AOT HLO artifacts through PJRT) → broker → drainer;
//! 4. results are validated, summarized, and the Fig. 8-style timeline is
//!    plotted.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_pipeline_e2e
//! ```

use sprobench::config::{expand_experiments, yaml};
use sprobench::coordinator::run_wall;
use sprobench::postprocess::{ascii_plot, ascii_table, validate_results};
use sprobench::runtime::RuntimeFactory;
use sprobench::util::units::{fmt_count, fmt_micros, fmt_rate_bytes};
use sprobench::workflow::WorkflowManager;

const MASTER_CONFIG: &str = "
benchmark:
  name: e2e
  seed: 42
  duration: 4s
  warmup: 500ms
workload:
  pattern: constant
  rate: 150K
  event_bytes: 27
  sensors: 1024
broker:
  partitions: 4
engine:
  framework: flink
  parallelism: 4
  batch_size: 1024
  window: 1s
  slide: 500ms
  threshold_f: 80.0
metrics:
  sample_interval: 250ms
experiments:
  - name: e2e-passthrough
    engine.pipeline: passthrough
  - name: e2e-cpu
    engine.pipeline: cpu
  - name: e2e-mem
    engine.pipeline: mem
";

fn main() {
    let rtf = RuntimeFactory::default_dir();
    let use_hlo = rtf.available();
    if !use_hlo {
        eprintln!("artifacts/ missing — running native compute (run `make artifacts` for the full stack)");
    }

    let mut doc = yaml::parse(MASTER_CONFIG).expect("master config parses");
    sprobench::config::overlay(&mut doc, "engine.use_hlo", sprobench::util::json::Json::Bool(use_hlo));
    let experiments = expand_experiments(&doc).expect("config expands");
    println!(
        "master config expanded into {} experiments; executing via workflow manager…\n",
        experiments.len()
    );

    let wm = WorkflowManager::new("runs");
    let mut rows = Vec::new();
    let outcomes = wm
        .run_all(&experiments, |exp, dir| {
            dir.step(&format!("pipeline={}", exp.config.engine.pipeline.name()));
            let (summary, store) = run_wall(
                &exp.config,
                exp.config.engine.use_hlo.then(|| rtf.clone()),
            )?;
            std::fs::write(
                dir.metrics_dir().join("series.json"),
                store.to_json().to_pretty(),
            )
            .map_err(|e| e.to_string())?;
            let results = summary.to_json();
            let violations = validate_results(&results);
            if !violations.is_empty() {
                return Err(format!("validation failed: {violations:?}"));
            }
            dir.step("validated");

            let e2e = summary
                .latency_at(sprobench::metrics::MeasurementPoint::EndToEnd)
                .cloned();
            rows.push(vec![
                summary.pipeline.to_string(),
                summary.generated.to_string(),
                summary.emitted.to_string(),
                format!("{} ev/s", fmt_count(summary.processed_rate)),
                fmt_rate_bytes(summary.offered_bytes_rate),
                e2e.map(|h| format!("{} / {}", fmt_micros(h.p50), fmt_micros(h.p99)))
                    .unwrap_or_else(|| "-".into()),
                summary.gc_young_count.to_string(),
            ]);

            // Fig. 8-style timeline for the CPU pipeline.
            if summary.pipeline == "cpu" {
                if let Some(series) = store.get("throughput.proc_out.eps") {
                    println!(
                        "{}",
                        ascii_plot(&series.normalized(), 60, 10, "cpu pipeline: throughput over normalized runtime")
                    );
                }
                if let Some(series) = store.get("latency.end_to_end.p50_us") {
                    println!(
                        "{}",
                        ascii_plot(&series.normalized(), 60, 8, "cpu pipeline: e2e p50 latency over normalized runtime")
                    );
                }
            }
            Ok(results)
        })
        .expect("workflow run");

    println!(
        "{}",
        ascii_table(
            &["pipeline", "generated", "emitted", "throughput", "bytes", "e2e p50/p99", "GC"],
            &rows
        )
    );
    for o in &outcomes {
        println!("run dir: {}", o.dir.display());
    }
    println!("\nE2E OK — {} pipelines executed, validated, and archived", outcomes.len());
}
