//! Custom processing logic (paper Sec. 3.3: "users can also define custom
//! processing logic tailored to their specific benchmarking objectives
//! with minimal modifications").
//!
//! This example defines a user **operator** — an alert filter that keeps
//! only readings above a threshold and enriches them with a severity tag —
//! registers it in an [`OperatorRegistry`] under the name `alert_filter`,
//! and runs it through the full stack from a declarative YAML pipeline
//! spec (`ops: [...]`) via `StepFactory::with_registry` +
//! `Engine::run_with_factory`.  The same spec works from the CLI:
//! `sprobench run --config bench.yaml --pipeline-spec alert.yaml`.
//!
//! ```bash
//! cargo run --release --example custom_pipeline
//! ```

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use sprobench::broker::{Broker, BrokerConfig, Record};
use sprobench::config::{self, BenchConfig};
use sprobench::engine::Engine;
use sprobench::metrics::{LatencyRecorder, ThroughputRecorder};
use sprobench::pipelines::{Operator, OperatorRegistry, RowBatch, StepFactory, StepStats};
use sprobench::postprocess::ascii_table;
use sprobench::util::clock;
use sprobench::wgen::{Fleet, GeneratorConfig, Pattern};

/// The user-defined operator: filter + enrich.  Rows above the threshold
/// stay in the batch (so further operators could chain after it); each is
/// also serialized and emitted with a severity tag.
struct AlertFilter {
    threshold_c: f32,
    stats: StepStats,
}

impl Operator for AlertFilter {
    fn name(&self) -> &str {
        "alert_filter"
    }

    fn apply(
        &mut self,
        _now_micros: u64,
        rows: &mut RowBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.stats.events_in += rows.len() as u64;
        let threshold = self.threshold_c;
        rows.retain(|_, v| v > threshold);
        for i in 0..rows.len() {
            let severity = if rows.vals[i] > threshold + 15.0 {
                "critical"
            } else {
                "warning"
            };
            let payload = format!(
                "{{\"id\":{},\"t\":{:.2},\"sev\":\"{severity}\"}}",
                rows.keys[i], rows.vals[i]
            );
            out.push(Record::new(rows.keys[i], payload.into_bytes(), rows.ts[i]));
            self.stats.events_out += 1;
            self.stats.alerts += 1;
        }
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

fn main() {
    let mut cfg = BenchConfig::default();
    cfg.bench.name = "custom".into();
    cfg.bench.duration_micros = 1_500_000;
    cfg.bench.warmup_micros = 0;
    cfg.workload.rate = 80_000;
    cfg.engine.parallelism = 2;

    // The declarative spec a user would put under `engine.pipeline` (or in
    // a `--pipeline-spec` file); `alert_filter` resolves in the registry.
    let spec_yaml = "
ops:
  - alert_filter:
      threshold_c: 30.0
";
    let doc = config::yaml::parse(spec_yaml).expect("spec yaml");
    cfg.engine.pipeline_spec = Some(config::parse_pipeline_spec(&doc).expect("spec"));
    cfg.validate().expect("config validates");

    // The one-line hook: register a builder for the custom operator name.
    let mut registry = OperatorRegistry::new();
    registry.register(
        "alert_filter",
        Box::new(|params, _ctx| {
            let threshold_c = params
                .get("threshold_c")
                .and_then(|v| v.as_f64())
                .ok_or("alert_filter needs `threshold_c:`")? as f32;
            Ok(Box::new(AlertFilter {
                threshold_c,
                stats: StepStats::default(),
            }) as Box<dyn Operator>)
        }),
    );
    let factory = Arc::new(StepFactory::with_registry(&cfg, None, Arc::new(registry)));

    let clk = clock::wall();
    let broker = Broker::new(BrokerConfig::from_section(&cfg.broker), clk.clone());
    let in_topic = broker.create_topic("ingest");
    let out_topic = broker.create_topic("egest");
    let drain = broker.subscribe("egest", "downstream", 1);
    let drainer = std::thread::spawn(move || {
        let mut n = 0u64;
        loop {
            match drain.poll(0, 2048) {
                Ok(Some(b)) => {
                    n += b.record_count() as u64;
                    drain.commit(b.partition, b.next_offset);
                }
                Ok(None) => std::thread::sleep(std::time::Duration::from_millis(1)),
                Err(_) => return n,
            }
        }
    });

    let tp = Arc::new(ThroughputRecorder::new());
    let lat = Arc::new(LatencyRecorder::new());
    let stop = Arc::new(AtomicBool::new(false));

    // Fleet in the background, engine on this thread.
    let fleet_handle = {
        let broker = broker.clone();
        let topic = in_topic.clone();
        let clk = clk.clone();
        let tp = tp.clone();
        let lat = lat.clone();
        let stop = stop.clone();
        let gen_cfg = GeneratorConfig::from_config(&cfg);
        let duration = cfg.bench.duration_micros;
        std::thread::spawn(move || {
            let fleet = Fleet::new(gen_cfg, clk, tp, lat);
            let r = fleet.run(&broker, &topic, duration, &stop, |share| Pattern::Constant {
                rate: share,
            });
            topic.close();
            r
        })
    };
    let engine = Engine::new(&cfg, clk, tp, lat);
    let report = engine
        .run_with_factory(&broker, "ingest", &out_topic, &stop, 30_000_000, factory, None)
        .expect("engine run");
    let fleet = fleet_handle.join().expect("fleet");
    broker.shutdown();
    let alerts_forwarded = drainer.join().expect("drainer");

    let total_alerts: u64 = report.tasks.iter().map(|t| t.step.alerts).sum();
    let rows = vec![
        vec!["events generated".into(), fleet.events.to_string()],
        vec!["events processed".into(), report.events_in.to_string()],
        vec!["alerts forwarded".into(), alerts_forwarded.to_string()],
        vec![
            "alert fraction".into(),
            format!("{:.1}%", 100.0 * total_alerts as f64 / report.events_in.max(1) as f64),
        ],
    ];
    println!("{}", ascii_table(&["metric", "value"], &rows));
    // Per-operator stats flow through the engine report.
    let (op_name, op_stats) = &report.operators[0];
    assert_eq!(op_name, "alert_filter");
    assert_eq!(op_stats.alerts, total_alerts);
    assert_eq!(report.events_in, fleet.events, "custom operator must drain");
    assert_eq!(alerts_forwarded, total_alerts);
    assert!(alerts_forwarded > 0 && alerts_forwarded < fleet.events);
    println!("custom_pipeline OK — registry operator `alert_filter` ran through the full stack");
}
