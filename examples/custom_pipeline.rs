//! Custom processing logic (paper Sec. 3.3: "users can also define custom
//! processing logic tailored to their specific benchmarking objectives
//! with minimal modifications").
//!
//! This example defines a user pipeline — an **alert filter** that parses
//! sensor events, keeps only readings above a threshold, enriches them
//! with a severity tag, and forwards them — and runs it through the full
//! stack with `StepFactory::custom` + `Engine::run_with_factory`.
//!
//! ```bash
//! cargo run --release --example custom_pipeline
//! ```

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use sprobench::broker::{Broker, BrokerConfig, Record};
use sprobench::config::BenchConfig;
use sprobench::engine::{Engine, EventBatch};
use sprobench::metrics::{LatencyRecorder, ThroughputRecorder};
use sprobench::pipelines::{PipelineStep, StepFactory, StepStats};
use sprobench::postprocess::ascii_table;
use sprobench::util::clock;
use sprobench::wgen::{Fleet, GeneratorConfig, Pattern};

/// The user-defined step: filter + enrich.
struct AlertFilter {
    threshold_c: f32,
    stats: StepStats,
}

impl PipelineStep for AlertFilter {
    fn name(&self) -> &'static str {
        "alert-filter"
    }

    fn process(
        &mut self,
        _now_micros: u64,
        _records: &[Record],
        batch: &EventBatch,
        out: &mut Vec<Record>,
    ) -> Result<(), String> {
        self.stats.events_in += batch.len() as u64;
        for i in 0..batch.len() {
            if batch.temps[i] > self.threshold_c {
                let severity = if batch.temps[i] > self.threshold_c + 15.0 {
                    "critical"
                } else {
                    "warning"
                };
                let payload = format!(
                    "{{\"id\":{},\"t\":{:.2},\"sev\":\"{severity}\"}}",
                    batch.ids[i], batch.temps[i]
                );
                out.push(Record::new(batch.ids[i], payload.into_bytes(), batch.gen_ts[i]));
                self.stats.events_out += 1;
                self.stats.alerts += 1;
            }
        }
        Ok(())
    }

    fn stats(&self) -> StepStats {
        self.stats
    }
}

fn main() {
    let mut cfg = BenchConfig::default();
    cfg.bench.name = "custom".into();
    cfg.bench.duration_micros = 1_500_000;
    cfg.bench.warmup_micros = 0;
    cfg.workload.rate = 80_000;
    cfg.engine.parallelism = 2;

    let clk = clock::wall();
    let broker = Broker::new(BrokerConfig::from_section(&cfg.broker), clk.clone());
    let in_topic = broker.create_topic("ingest");
    let out_topic = broker.create_topic("egest");
    let drain = broker.subscribe("egest", "downstream", 1);
    let drainer = std::thread::spawn(move || {
        let mut n = 0u64;
        loop {
            match drain.poll(0, 2048) {
                Ok(Some(b)) => {
                    n += b.record_count() as u64;
                    drain.commit(b.partition, b.next_offset);
                }
                Ok(None) => std::thread::sleep(std::time::Duration::from_millis(1)),
                Err(_) => return n,
            }
        }
    });

    let tp = Arc::new(ThroughputRecorder::new());
    let lat = Arc::new(LatencyRecorder::new());
    let stop = Arc::new(AtomicBool::new(false));

    // The one-line hook: a factory producing the user's step.
    let factory = Arc::new(StepFactory::custom(
        &cfg,
        Box::new(|_start| {
            Ok(Box::new(AlertFilter {
                threshold_c: 30.0,
                stats: StepStats::default(),
            }) as Box<dyn PipelineStep>)
        }),
    ));

    // Fleet in the background, engine on this thread.
    let fleet_handle = {
        let broker = broker.clone();
        let topic = in_topic.clone();
        let clk = clk.clone();
        let tp = tp.clone();
        let lat = lat.clone();
        let stop = stop.clone();
        let gen_cfg = GeneratorConfig::from_config(&cfg);
        let duration = cfg.bench.duration_micros;
        std::thread::spawn(move || {
            let fleet = Fleet::new(gen_cfg, clk, tp, lat);
            let r = fleet.run(&broker, &topic, duration, &stop, |share| Pattern::Constant {
                rate: share,
            });
            topic.close();
            r
        })
    };
    let engine = Engine::new(&cfg, clk, tp, lat);
    let report = engine
        .run_with_factory(&broker, "ingest", &out_topic, &stop, 30_000_000, factory, None)
        .expect("engine run");
    let fleet = fleet_handle.join().expect("fleet");
    broker.shutdown();
    let alerts_forwarded = drainer.join().expect("drainer");

    let total_alerts: u64 = report.tasks.iter().map(|t| t.step.alerts).sum();
    let rows = vec![
        vec!["events generated".into(), fleet.events.to_string()],
        vec!["events processed".into(), report.events_in.to_string()],
        vec!["alerts forwarded".into(), alerts_forwarded.to_string()],
        vec![
            "alert fraction".into(),
            format!("{:.1}%", 100.0 * total_alerts as f64 / report.events_in.max(1) as f64),
        ],
    ];
    println!("{}", ascii_table(&["metric", "value"], &rows));
    assert_eq!(report.events_in, fleet.events, "custom step must drain");
    assert_eq!(alerts_forwarded, total_alerts);
    assert!(alerts_forwarded > 0 && alerts_forwarded < fleet.events);
    println!("custom_pipeline OK — user-defined step ran through the full stack");
}
