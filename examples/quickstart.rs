//! Quickstart: run one small SProBench experiment end to end on this
//! machine and print the standard report.
//!
//! ```bash
//! make artifacts            # once: AOT-compile the pipeline kernels
//! cargo run --release --example quickstart
//! ```

use sprobench::bench::scenarios;
use sprobench::coordinator::run_wall;
use sprobench::postprocess::{ascii_table, validate_results};
use sprobench::runtime::RuntimeFactory;
use sprobench::util::units::{fmt_count, fmt_micros};

fn main() {
    // A 2-second CPU-intensive run at 100K events/s, parallelism 4.
    let mut cfg = scenarios::wall_base("quickstart");
    let rtf = RuntimeFactory::default_dir();
    cfg.engine.use_hlo = rtf.available();
    if !cfg.engine.use_hlo {
        eprintln!("artifacts/ not built — falling back to native compute (run `make artifacts`)");
    }

    let (summary, _store) =
        run_wall(&cfg, cfg.engine.use_hlo.then(|| rtf)).expect("benchmark run failed");

    let e2e = summary
        .latency_at(sprobench::metrics::MeasurementPoint::EndToEnd)
        .expect("latency recorded");
    let rows = vec![
        vec!["events generated".into(), summary.generated.to_string()],
        vec!["events processed".into(), summary.processed.to_string()],
        vec!["events emitted".into(), summary.emitted.to_string()],
        vec![
            "throughput".into(),
            format!("{} ev/s", fmt_count(summary.processed_rate)),
        ],
        vec![
            "e2e latency p50/p99".into(),
            format!("{} / {}", fmt_micros(e2e.p50), fmt_micros(e2e.p99)),
        ],
        vec!["GC young".into(), summary.gc_young_count.to_string()],
        vec!["energy".into(), format!("{:.1} J", summary.energy_joules)],
    ];
    println!("{}", ascii_table(&["metric", "value"], &rows));

    let violations = validate_results(&summary.to_json());
    assert!(violations.is_empty(), "validation failed: {violations:?}");
    println!("quickstart OK — results validated");
}
