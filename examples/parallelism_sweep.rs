//! Parallelism sweep (the Fig. 7 experiment, interactive).
//!
//! One master config with an `experiments:` matrix sweeps the engine
//! parallelism {1, 2, 4, 8, 16} over the CPU-intensive pipeline — the
//! paper's "maintaining a consistent parallelism … test multiple
//! workloads without creating multiple configuration files" feature in
//! reverse.
//!
//! ```bash
//! make artifacts && cargo run --release --example parallelism_sweep
//! ```

use sprobench::config::{expand_experiments, yaml};
use sprobench::coordinator::run_wall;
use sprobench::metrics::MeasurementPoint;
use sprobench::postprocess::ascii_table;
use sprobench::runtime::RuntimeFactory;
use sprobench::util::units::{fmt_count, fmt_micros};

const SWEEP: &str = "
benchmark:
  name: fig7-sweep
  duration: 1500ms
  warmup: 300ms
workload:
  rate: 400K
  event_bytes: 27
engine:
  pipeline: cpu
  batch_size: 1024
broker:
  partitions: 16
metrics:
  sample_interval: 250ms
experiments:
  - name: p1
    engine.parallelism: 1
  - name: p2
    engine.parallelism: 2
  - name: p4
    engine.parallelism: 4
  - name: p8
    engine.parallelism: 8
  - name: p16
    engine.parallelism: 16
";

fn main() {
    let rtf = RuntimeFactory::default_dir();
    let use_hlo = rtf.available();
    let mut doc = yaml::parse(SWEEP).expect("sweep config");
    sprobench::config::overlay(
        &mut doc,
        "engine.use_hlo",
        sprobench::util::json::Json::Bool(use_hlo),
    );
    let exps = expand_experiments(&doc).expect("expand");
    let mut rows = Vec::new();
    let mut baseline_rate = 0.0;
    for exp in &exps {
        let (summary, _) = run_wall(&exp.config, use_hlo.then(|| rtf.clone())).expect("run");
        if baseline_rate == 0.0 {
            baseline_rate = summary.processed_rate;
        }
        let e2e = summary.latency_at(MeasurementPoint::EndToEnd).expect("e2e");
        rows.push(vec![
            summary.parallelism.to_string(),
            format!("{} ev/s", fmt_count(summary.processed_rate)),
            format!("{:.2}x", summary.processed_rate / baseline_rate),
            fmt_micros(e2e.p50),
            fmt_micros(e2e.p99),
            summary.gc_young_count.to_string(),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            &["parallelism", "throughput", "speedup", "e2e p50", "e2e p99", "GC young"],
            &rows
        )
    );
    println!("expected shape (paper Fig. 7): near-linear speedup flattening at high P; latency rising with P");
}
