//! Broker scaling demo (the Fig. 6 experiment, interactive).
//!
//! Steps the offered load and shows the 1:1 relation between generator
//! output and broker throughput plus the broker-latency trend.
//!
//! ```bash
//! cargo run --release --example broker_scaling
//! ```

use sprobench::bench::scenarios;
use sprobench::coordinator::run_wall;
use sprobench::metrics::MeasurementPoint;
use sprobench::postprocess::ascii_table;
use sprobench::util::stats::linear_fit;
use sprobench::util::units::{fmt_count, fmt_micros};

fn main() {
    let rates = [50_000u64, 100_000, 200_000, 400_000];
    let mut rows = Vec::new();
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &rate in &rates {
        let mut cfg = scenarios::fig6(rate);
        cfg.bench.duration_micros = 1_500_000;
        let (summary, _) = run_wall(&cfg, None).expect("run");
        let lat = summary
            .latency_at(MeasurementPoint::BrokerIn)
            .expect("broker latency");
        xs.push(summary.offered_rate);
        ys.push(summary.processed_rate);
        rows.push(vec![
            format!("{} ev/s", fmt_count(rate as f64)),
            format!("{} ev/s", fmt_count(summary.offered_rate)),
            format!("{} ev/s", fmt_count(summary.processed_rate)),
            fmt_micros(lat.p50),
            fmt_micros(lat.p99),
        ]);
    }
    println!(
        "{}",
        ascii_table(
            &["requested", "offered", "broker out", "broker p50", "broker p99"],
            &rows
        )
    );
    let fit = linear_fit(&xs, &ys);
    println!(
        "linear fit: out = {:.4} x offered + {:.0}  (R^2 = {:.5}) — the paper's 1:1 line",
        fit.slope, fit.intercept, fit.r2
    );
}
